"""Observability tour: metrics registry, latency histograms, span traces.

Ingests a small graph through the D4M connector, then walks the
surfaces `repro.obs` exposes:

  1. ``DBserver.metrics()``    — per-table/per-shard counters + p50/p99
                                 + derived health gauges
  2. the raw ``Registry``      — labeled series, aggregation, snapshots
  3. the ``Tracer``            — nested spans, trace ids, slow-op log,
                                 flight recorder, Chrome export
  4. the exporters             — Prometheus text (with exemplars),
                                 health report, ``DBserver.debug_bundle``

  PYTHONPATH=src python examples/observability.py
"""
import json

import numpy as np

from repro.db import dbinit, dbsetup
from repro.obs import default_registry, default_tracer, set_enabled

dbinit()
DB = dbsetup("obsdemo", num_shards=4, capacity_per_shard=1 << 14,
             batch_cap=4096, id_capacity=1 << 16,  # ~16k ids/shard
             memtable_cap=2048)  # small memtable: flushes show up in health
T = DB["edges", "edgesT"]

# --- generate some traffic -------------------------------------------------
rng = np.random.default_rng(0)
for batch in range(8):
    n = 2000
    src = np.asarray([f"v{int(i):05d}" for i in
                      rng.zipf(1.6, n) % 30_000], object)
    dst = np.asarray([f"v{int(i):05d}" for i in
                      rng.integers(0, 30_000, n)], object)
    T.put_triple(src, dst, np.ones(n))
for _ in range(50):
    v = f"v{int(rng.integers(0, 30_000)):05d},"
    T[v, :]                       # point reads (fused single-dispatch)
T["v00100,:,v00200,", :]          # a range read (fused fence-to-fence scan)

# --- 1. the server-level snapshot ------------------------------------------
m = DB.metrics()
tab = m["tables"]["edges"]
lat = tab["latency_s"]
print(f"engine={tab['engine']}  "
      f"flushes={tab['counters']['flushes']}  "
      f"fused_dispatches={tab['counters']['fused_dispatches']}")
for op in ("ingest", "query", "scan"):
    s = lat[op]
    if s["count"]:
        print(f"  {op:6s} n={s['count']:<5d} p50={s['p50'] * 1e6:8.0f}us "
              f"p99={s['p99'] * 1e6:8.0f}us")
# per-shard counters are the hot-shard detector: zipf-distributed row
# keys get dictionary ids in first-seen order, so the skewed head of the
# distribution lands together — visible here, invisible in table totals
for shard, rec in sorted(tab["shards"].items()):
    print(f"  shard {shard}: ingested={rec['ingest_entries']:>6,} "
          f"point_queries={rec['point_queries']:>4}")
DB.dump_metrics("/tmp/obsdemo_metrics.json")
print("full snapshot -> /tmp/obsdemo_metrics.json")

# --- 2. the registry directly ----------------------------------------------
reg = default_registry()
probes = reg.aggregate("lsm_runs_probed", table="edges")
skips = reg.aggregate("lsm_runs_skipped", table="edges")
print(f"bloom/fence filtering: probed={probes} skipped={skips}")
h = reg.aggregate("db_op_latency_s", table="edges", op="query")
if h and h["count"]:
    print(f"query latency (merged across calls): mean={h['mean'] * 1e6:.0f}us "
          f"p999={h['p999'] * 1e6:.0f}us")

# --- 3. span traces --------------------------------------------------------
tr = default_tracer()
spans = tr.spans()
print(f"\n{len(spans)} spans in the ring; last query breakdown:")
for rec in [r for r in spans if r["name"] in
            ("query.fused", "dispatch", "host_sync")][-3:]:
    print(f"  {'  ' * rec['depth']}{rec['name']:<12s} "
          f"{rec['dur'] * 1e6:8.1f}us  (parent={rec['parent']})")
slow = tr.slow_ops()
if slow:
    worst = max(slow, key=lambda r: r["dur"])
    print(f"slow ops (>= {tr.slow_threshold_s * 1e3:.0f}ms): {len(slow)}, "
          f"worst = {worst['name']} at {worst['dur'] * 1e3:.1f}ms")
tr.export_chrome("/tmp/obsdemo_trace.json")
print("chrome trace -> /tmp/obsdemo_trace.json "
      "(load in chrome://tracing or ui.perfetto.dev)")

flights = tr.flight_recordings()
if flights:
    rec = flights[-1]
    print(f"flight recorder: {len(flights)} slow-op trees; last trace "
          f"{rec['trace']} root={rec['root']['name']} "
          f"({len(rec['spans'])} spans)")

# --- 4. exporters + debug bundle -------------------------------------------
from repro.obs import health_report, prometheus_text

health = m["tables"]["edges"]["health"]
print(f"\nhealth: read_amp={health['read_amplification']:.2f} "
      f"write_amp={health['write_amplification']:.2f} "
      f"retraces={health['retraces']}")
prom = prometheus_text()
exemplar_lines = [l for l in prom.splitlines() if "trace_id=" in l]
print(f"prometheus exposition: {len(prom.splitlines())} lines, "
      f"{len(exemplar_lines)} bucket exemplars linking to traces")
print(health_report(fmt="term").splitlines()[0], "... (health_report)")
DB.debug_bundle("/tmp/obsdemo_bundle.zip")
print("debug bundle -> /tmp/obsdemo_bundle.zip "
      "(metrics + prometheus + slow traces + config + geometry)")

# --- kill switch -----------------------------------------------------------
set_enabled(False)               # every instrument becomes a no-op
before = json.dumps(reg.snapshot("db_point_queries"))
T[f"v{int(rng.integers(0, 30_000)):05d},", :]
assert json.dumps(reg.snapshot("db_point_queries")) == before
set_enabled(True)
print("\nset_enabled(False) verified: reads leave no metric trace")
