"""Serve a small LM with batched requests (continuous prefill+decode engine).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    stats = serve_main(["--arch", "smollm-135m", "--reduced",
                        "--requests", "8", "--max-new", "16", "--slots", "4"])
    assert stats["tokens_out"] >= 8 * 8
