"""Graph analytics on the D4M store: Graph500 ingest, degree-table queries,
BFS via associative-array matmul, and the SpMV Pallas kernel.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Assoc
from repro.data.graph500 import graph500_triples
from repro.db import EdgeSchema, dbsetup
from repro.kernels.spmv import ell_from_coo, spmv_ell, spmv_ell_ref

SCALE = 10

# --- ingest with the D4M 2.0 schema (edge + transpose + degree tables) -----
server = dbsetup("analytics", num_shards=4, capacity_per_shard=1 << 17,
                 batch_cap=1 << 15, id_capacity=1 << 20)
g = EdgeSchema(server, "g500")
rows, cols, vals = graph500_triples(SCALE, 16, seed=7)
t0 = time.time()
g.put_triple(rows, cols, vals)
print(f"ingested {len(rows):,} edges in {time.time() - t0:.2f}s "
      f"({len(rows) / (time.time() - t0):,.0f} edges/s), nnz={g.nnz():,}")

# --- degree-table analytics (the Fig. 4 query-planning path) ---------------
deg = g.deg.degrees(":")
top = (deg[:, "OutDeg,"]).triples()
hub = top[0][np.argmax(top[2])]
print(f"max out-degree vertex: {hub} (deg {int(top[2].max())})")
hubs = g.deg.vertices_with_degree(float(top[2].max()), "out", tol=2.0)
print(f"vertices within 2x of max degree: {len(hubs)}")

# --- BFS from the hub via assoc matmul (paper Fig. 1) -----------------------
frontier = Assoc(np.asarray(["seed"], object), np.asarray([hub], object), 1.0)
visited = set()
for hop in range(3):
    adj = g[("".join(str(v) + "," for v in frontier.col)), :]
    frontier = frontier * adj
    new = set(frontier.col) - visited
    visited |= new
    print(f"hop {hop + 1}: frontier {len(frontier.col):>6,} vertices "
          f"({len(new):,} new)")

# --- same BFS step on the SpMV kernel (TPU hot path, interpret-validated) ---
rid = server.keydict.lookup(rows)
cid = server.keydict.lookup(cols)
n = int(max(rid.max(), cid.max())) + 1
ell_cols, ell_vals = ell_from_coo(np.sort(cid), rid[np.argsort(cid)],
                                  np.ones(len(rid), np.float32), n)
x = np.zeros(n, np.float32)
x[server.keydict.get(hub)] = 1.0
y_kernel = spmv_ell(jnp.asarray(ell_cols), jnp.asarray(ell_vals),
                    jnp.asarray(x))
y_ref = spmv_ell_ref(jnp.asarray(ell_cols), jnp.asarray(ell_vals),
                     jnp.asarray(x))
np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref), rtol=1e-5)
print(f"SpMV kernel BFS step: {int((np.asarray(y_kernel) > 0).sum()):,} "
      f"reachable vertices (matches jnp oracle)")
