"""Quickstart: associative arrays + the paper's Listing-1 database workflow.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Assoc
from repro.db import dbinit, dbsetup, delete, put

# --- associative arrays (paper §II) ---------------------------------------
A = Assoc("alice,alice,bob,carl,", "bob,carl,alice,alice,", [1.0, 2.0, 3.0, 4.0])
print("A =\n", A)

print("\nrow query     A['alice,',:]        ->\n", A["alice,", :])
print("\nprefix query  A['al*,',:]          ->\n", A["al*,", :])
print("\nrange query   A['alice,:,bob,',:]  ->\n", A["alice,:,bob,", :])
print("\nvalue filter  A == 4.0             ->\n", A == 4.0)

B = Assoc("alice,dan,", "carl,alice,", [10.0, 20.0])
print("\nA + B ->\n", A + B)
print("\nA & B ->\n", A & B)

# BFS == matrix-vector multiply (paper Fig. 1)
seed = Assoc("q,", "alice,", 1.0)
print("\nneighbors of alice via seed*A ->\n", seed * A)

# --- database workflow (paper Listing 1) ----------------------------------
dbinit()
DB = dbsetup("mydb02", num_shards=4, capacity_per_shard=4096,
             batch_cap=2048, id_capacity=1 << 16)
Tedge = DB["my_Tedge", "my_TedgeT"]
TedgeDeg = DB["my_TedgeDeg"]

put(Tedge, A)
print("\nTedge['alice,',:] ->\n", Tedge["alice,", :])
print("\nTedge[:,'alice,'] (transpose-routed) ->\n", Tedge[:, "alice,"])

delete(Tedge)
delete(TedgeDeg)
print("\ntables after delete:", DB.ls())
