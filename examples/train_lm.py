"""End-to-end training driver: reduced smollm-family LM trained for a few
hundred steps on CPU, data streamed from the D4M-store pipeline, with a
checkpoint/restart halfway through (the fault-tolerance path).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import main as train_main


def run(steps: int = 200):
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = steps // 2
        print(f"== phase 1: steps 0..{half} ==")
        train_main(["--arch", "smollm-135m", "--reduced",
                    "--steps", str(half), "--batch", "8", "--seq", "128",
                    "--ckpt-dir", ckpt, "--ckpt-every", "20"])
        print(f"== simulated failure; restart from checkpoint ==")
        losses = train_main(["--arch", "smollm-135m", "--reduced",
                             "--steps", str(steps), "--batch", "8",
                             "--seq", "128", "--ckpt-dir", ckpt,
                             "--resume"])
        assert losses[-1] < losses[0], "loss should decrease"
        print("training-loss sanity: PASS")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    run(ap.parse_args().steps)
