"""Roofline table generator: reads launch/dryrun JSON records and emits the
EXPERIMENTS.md §Roofline markdown table + CSV."""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load_records(path: str = "experiments/dryrun") -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.extend(json.load(fh))
    # dedupe by (arch, shape, mesh), last wins
    seen = {}
    for r in recs:
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def fmt_ms(x) -> str:
    return f"{x * 1e3:,.1f}"


def table(recs: List[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | kind | compute ms | memory ms | collective ms | "
        "bottleneck | useful | HBM GB/dev | note |",
        "|---|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = [r for r in recs if r.get("mesh") == mesh]
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in recs:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"SKIP | — | — | {r['skipped'][:60]} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"ERROR | — | — | {r['error'][:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_ms(r['compute_s'])} | {fmt_ms(r['memory_s'])} | "
            f"{fmt_ms(r['collective_s'])} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | "
            f"{r['hbm_bytes_per_device'] / 1e9:.1f} | |")
    return "\n".join(lines)


def csv(recs: List[dict]) -> str:
    cols = ["arch", "shape", "mesh", "kind", "compute_s", "memory_s",
            "collective_s", "bottleneck", "useful_ratio",
            "flops_per_device", "bytes_per_device", "link_bytes_per_device",
            "hbm_bytes_per_device", "compile_s"]
    out = [",".join(cols)]
    for r in recs:
        if "error" in r or "skipped" in r:
            continue
        out.append(",".join(str(r.get(c, "")) for c in cols))
    return "\n".join(out)


def main():
    recs = load_records()
    print(f"{len(recs)} records")
    for mesh in ("16x16", "2x16x16"):
        n = sum(1 for r in recs if r.get("mesh") == mesh)
        print(f"\n== mesh {mesh} ({n} cells) ==")
        print(table(recs, mesh))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.csv", "w") as f:
        f.write(csv(recs))
    print("\nwrote experiments/roofline.csv")


if __name__ == "__main__":
    main()
