"""Paper Fig. 4: query rate (edges returned/s) vs queried-vertex degree.

Protocol mirrors §IV-B: ingest a large power-law graph + degree table
(D4M 2.0 schema, 8 ingestors), pick vertices with out/in degree near
{1, 10, 100, 1000} via the degree table, run the four query types —
single-vertex row (SVR), single-vertex column (SVC), multi-vertex row
(MVR, 5 vertices), multi-vertex column (MVC) — and measure edges/s.
Column queries exercise the transpose-table routing.

``fused_read_compare`` is the read-path A/B behind ``BENCH_query.json``:
point-read latency of the fused single-dispatch LSM path vs the per-run
baseline as the number of resident runs per shard grows (fig4 SVR/SVC
latency is dispatch-bound, so fused wins once several runs are resident).
``scan_read_compare`` is the range-scan A/B: one fused fence-to-fence
dispatch per shard vs expanding the range into an id list of point
queries (the pre-scan selector path), swept over range lengths.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.data.graph500 import graph500_triples
from repro.db import EdgeSchema, NaiveTable, dbsetup
from repro.db.kvstore import ShardedTable


def build_graph(scale: int = 13, ingestors: int = 8, use_pallas: bool = False):
    server = dbsetup("querybench", num_shards=4,
                     capacity_per_shard=1 << 21, batch_cap=1 << 16,
                     id_capacity=1 << 22, use_pallas=use_pallas)
    g = EdgeSchema(server, "g")
    naive = NaiveTable("naive")
    for i in range(ingestors):
        r, c, v = graph500_triples(scale, 16, seed=300 + i)
        g.put_triple(r, c, v)
        naive.put_triple(r, c, v)
    return g, naive


def _measure(fn, reps: int) -> tuple:
    t0 = time.time()
    edges = 0
    for _ in range(reps):
        a = fn()
        edges += a.nnz()
    return edges, time.time() - t0


def fig4(scale: int = 13, degrees=(1, 10, 100, 1000), reps: int = 5):
    g, naive = build_graph(scale)
    rng = np.random.default_rng(0)
    rows = []
    for target in degrees:
        for kind, sel in (("out", "row"), ("in", "col")):
            vs = g.deg.vertices_with_degree(target, kind=kind)
            if len(vs) == 0:
                continue
            single = str(rng.choice(vs)) + ","
            multi = "".join(str(v) + "," for v in
                            rng.choice(vs, size=min(5, len(vs)),
                                       replace=False))
            for qname, q in (("SV", single), ("MV", multi)):
                if sel == "row":
                    fn = lambda q=q: g[q, :]
                    fn_n = lambda q=q: naive[q, :]
                else:
                    fn = lambda q=q: g[:, q]
                    fn_n = lambda q=q: naive[:, q]
                fn()  # warmup (compile)
                edges, wall = _measure(fn, reps)
                edges_n, wall_n = _measure(fn_n, max(reps // 5, 1))
                label = f"{qname}{'R' if sel == 'row' else 'C'}"
                rows.append({
                    "degree": target, "query": label,
                    "edges_returned": edges // reps,
                    "opt_edges_per_s": edges / wall,
                    "naive_edges_per_s": edges_n / wall_n if edges_n else 0.0,
                })
                print(f"deg~{target:>5} {label}: {edges // reps:>7,} edges "
                      f"opt={edges / wall:>12,.0f} e/s "
                      f"naive={(edges_n / wall_n if edges_n else 0):>12,.0f} e/s")
    return rows


def _build_lsm_serving_state(n_l0_runs: int, with_levels: bool,
                             shards: int = 2, mem: int = 4096,
                             tail: int = 256, seed: int = 0,
                             transpose: bool = False,
                             col_space: int = 1 << 10):
    """An LSM table in point-read serving shape: ``n_l0_runs`` resident L0
    runs (plus two leveled runs when ``with_levels``) and a small unflushed
    memtable tail. Key ranges overlap across runs so blooms mostly hit —
    the per-run baseline gets no cheap range-skips. ``transpose=True``
    builds an engine-maintained pair (column-selector benches);
    ``col_space`` widens the col universe so col ranges behave like row
    ranges."""
    st = ShardedTable("qbench" + ("_pair" if transpose else ""),
                      num_shards=shards,
                      capacity_per_shard=1 << 18, batch_cap=mem,
                      id_capacity=1 << 22, memtable_cap=mem,
                      l0_slots=max(8, n_l0_runs + 2), engine="lsm",
                      transpose=transpose)
    rng = np.random.default_rng(seed)

    def fill(n):
        st.insert(rng.integers(0, 1 << 22, n).astype(np.int32),
                  rng.integers(0, col_space, n).astype(np.int32),
                  rng.normal(size=n).astype(np.float32))

    if with_levels:
        for _ in range(16):  # two L0 fills -> auto-majors land in L2
            fill(mem)
            st.flush()
        fill(mem)            # small merge -> resident L1 as well
        st.flush()
        st.major_compact()
    for _ in range(n_l0_runs):
        fill(mem)
        st.flush()
    fill(tail)              # unflushed memtable tail
    return st


def fused_read_compare(reps: int = 100, q_rows: int = 4,
                       out: str = None) -> dict:
    """Point-read latency A/B: fused single-dispatch vs per-run baseline,
    sweeping resident runs per shard (fig4 SVR-shaped tiny queries, where
    the per-run path is dispatch-bound). Writes ``BENCH_query.json``."""
    rng = np.random.default_rng(3)
    result = {"config": {"reps": reps, "q_rows": q_rows}, "rows": []}
    scenarios = [(2, False), (4, False), (6, False), (2, True)]
    for n_l0, with_levels in scenarios:
        st = _build_lsm_serving_state(n_l0, with_levels)
        resident = max(st._runs.resident_runs(s) for s in range(st.S))
        present = np.asarray(st.scan_shard(0)[0])
        qs = [np.unique(rng.choice(present, q_rows)).astype(np.int32)
              for _ in range(8)]
        timings = {}
        tails = {}
        for mode, fused in (("fused", True), ("per_run", False)):
            st.fused_reads = fused
            for q in qs:
                st.query_rows(q)  # warm both jit caches off the clock
            st._h_query.reset()  # per-mode latency histogram (obs registry)
            t0 = time.time()
            for i in range(reps):
                st.query_rows(qs[i % len(qs)])
            timings[mode] = (time.time() - t0) / reps * 1e6
            tails[mode] = st._h_query.percentiles()
        st.fused_reads = True
        row = {"resident_runs_per_shard": resident,
               "with_levels": with_levels,
               "fused_us_per_query": timings["fused"],
               "per_run_us_per_query": timings["per_run"],
               "fused_speedup": timings["per_run"] / timings["fused"],
               "fused_p50_us": tails["fused"]["p50"] * 1e6,
               "fused_p99_us": tails["fused"]["p99"] * 1e6,
               "per_run_p50_us": tails["per_run"]["p50"] * 1e6,
               "per_run_p99_us": tails["per_run"]["p99"] * 1e6,
               "fused_dispatches": st.engine_stats()["fused_dispatches"]}
        result["rows"].append(row)
        print(f"runs/shard={resident:2d} levels={with_levels} "
              f"fused={timings['fused']:8.1f}us "
              f"per-run={timings['per_run']:8.1f}us "
              f"speedup={row['fused_speedup']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    return result


def scan_read_compare(reps: int = 30, lengths=(64, 256, 1024),
                      out: str = None) -> dict:
    """Range-scan A/B: the fused fence-to-fence scan dispatch
    (``ShardedTable.scan_range``) vs id-list point expansion of the same
    ``[lo, lo+len)`` range (``query_rows(arange(lo, hi))`` — exactly what
    range selectors compiled to before the scan path existed). Emits
    ``scan_rows`` for ``BENCH_query.json``; the CI gate tracks the
    scan/point-expansion ratio."""
    rng = np.random.default_rng(11)
    st = _build_lsm_serving_state(4, True)   # levels + L0 runs + mem tail
    resident = max(st._runs.resident_runs(s) for s in range(st.S))
    present = np.asarray(st.scan_shard(0)[0])
    result = {"scan_config": {"reps": reps,
                              "resident_runs_per_shard": resident},
              "scan_rows": []}
    for length in lengths:
        los = [int(present[int(i)]) for i in
               rng.integers(0, max(len(present) - 1, 1), 8)]
        los = [min(lo, (1 << 22) - length) for lo in los]
        st.scan_range(los[0], los[0] + length)      # warm the jit caches
        st.query_rows(np.arange(los[0], los[0] + length, dtype=np.int32))
        d0 = st.engine_stats()["scan_dispatches"]
        st._h_scan.reset()  # per-mode latency histogram (obs registry)
        t0 = time.time()
        for i in range(reps):
            lo = los[i % len(los)]
            st.scan_range(lo, lo + length)
        scan_us = (time.time() - t0) / reps * 1e6
        scan_tail = st._h_scan.percentiles()
        dispatches = (st.engine_stats()["scan_dispatches"] - d0) / reps
        st._h_query.reset()
        t0 = time.time()
        for i in range(reps):
            lo = los[i % len(los)]
            st.query_rows(np.arange(lo, lo + length, dtype=np.int32))
        point_us = (time.time() - t0) / reps * 1e6
        point_tail = st._h_query.percentiles()
        row = {"range_len": length, "scan_us": scan_us,
               "point_expansion_us": point_us,
               "scan_speedup": point_us / scan_us,
               "scan_p50_us": scan_tail["p50"] * 1e6,
               "scan_p99_us": scan_tail["p99"] * 1e6,
               "point_expansion_p50_us": point_tail["p50"] * 1e6,
               "point_expansion_p99_us": point_tail["p99"] * 1e6,
               "scan_dispatches_per_call": dispatches}
        result["scan_rows"].append(row)
        print(f"range_len={length:5d} scan={scan_us:9.1f}us "
              f"point-expansion={point_us:10.1f}us "
              f"speedup={row['scan_speedup']:6.2f}x "
              f"dispatches/scan={dispatches:.2f}")
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    return result


def colsel_read_compare(reps: int = 30, lengths=(64, 256, 1024),
                        out: str = None) -> dict:
    """Column-selector A/B on an engine-maintained transpose PAIR: the
    transpose-routed fused scan (``scan_col_range``, a fence-bracketed
    range scan over ``A^T``) vs the O(nnz) full-scan-and-host-filter
    baseline (what column selectors execute on single tables), with the
    same-length ROW range scan as reference — the design target is column
    selectors within ~1.5x of row range scans, not O(nnz). Emits
    ``colsel_rows`` for ``BENCH_query.json``; the CI gate tracks the
    worst colsel/filter ratio (``colsel_vs_filter``)."""
    rng = np.random.default_rng(13)
    st = _build_lsm_serving_state(4, True, transpose=True,
                                  col_space=1 << 22)
    resident = max(st.t_store._runs.resident_runs(s)
                   for s in range(st.t_store.S))
    present_cols = np.sort(np.asarray(st.t_store.scan_shard(0)[0]))
    result = {"colsel_config": {"reps": reps,
                                "sibling_resident_runs_per_shard": resident},
              "colsel_rows": []}
    filter_reps = max(reps // 5, 3)
    for length in lengths:
        los = [int(present_cols[int(i)]) for i in
               rng.integers(0, max(len(present_cols) - 1, 1), 8)]
        los = [min(lo, (1 << 22) - length) for lo in los]
        st.scan_col_range(los[0], los[0] + length)   # warm the jit caches
        st.scan_range(los[0], los[0] + length)
        st.scan()
        d0 = st.t_store.engine_stats()["scan_dispatches"]
        st.t_store._h_scan.reset()
        t0 = time.time()
        for i in range(reps):
            lo = los[i % len(los)]
            st.scan_col_range(lo, lo + length)
        colsel_us = (time.time() - t0) / reps * 1e6
        colsel_tail = st.t_store._h_scan.percentiles()
        dispatches = (st.t_store.engine_stats()["scan_dispatches"] - d0) \
            / reps
        t0 = time.time()
        for i in range(filter_reps):  # O(nnz) full scan + host isin
            lo = los[i % len(los)]
            r, c, v = st.scan()
            keep = (c >= lo) & (c < lo + length)
            r, c, v = r[keep], c[keep], v[keep]
        filter_us = (time.time() - t0) / filter_reps * 1e6
        t0 = time.time()
        for i in range(reps):  # row-scan reference (same range length)
            lo = los[i % len(los)]
            st.scan_range(lo, lo + length)
        rowscan_us = (time.time() - t0) / reps * 1e6
        row = {"range_len": length, "colsel_us": colsel_us,
               "full_scan_filter_us": filter_us,
               "rowscan_us": rowscan_us,
               "colsel_speedup": filter_us / colsel_us,
               "colsel_vs_rowscan": colsel_us / rowscan_us,
               "colsel_p50_us": colsel_tail["p50"] * 1e6,
               "colsel_p99_us": colsel_tail["p99"] * 1e6,
               "sibling_scan_dispatches_per_call": dispatches}
        result["colsel_rows"].append(row)
        print(f"range_len={length:5d} colsel={colsel_us:9.1f}us "
              f"full-scan+filter={filter_us:10.1f}us "
              f"speedup={row['colsel_speedup']:6.2f}x "
              f"vs-rowscan={row['colsel_vs_rowscan']:.2f}x "
              f"dispatches/scan={dispatches:.2f}")
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {out}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fused-compare", action="store_true",
                    help="point-read A/B (BENCH_query.json artifact)")
    ap.add_argument("--scan-compare", action="store_true",
                    help="range-scan vs point-expansion A/B "
                         "(scan_rows in BENCH_query.json)")
    ap.add_argument("--colsel-compare", action="store_true",
                    help="column selector via transpose pair vs "
                         "full-scan-and-filter A/B "
                         "(colsel_rows in BENCH_query.json)")
    ap.add_argument("--out", default="BENCH_query.json")
    ap.add_argument("--reps", type=int, default=100)
    args = ap.parse_args()
    if args.fused_compare or args.scan_compare or args.colsel_compare:
        result = {}
        if args.fused_compare:
            result.update(fused_read_compare(reps=args.reps))
        if args.scan_compare:
            result.update(scan_read_compare(reps=max(args.reps // 2, 10)))
        if args.colsel_compare:
            result.update(colsel_read_compare(reps=max(args.reps // 2, 10)))
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")
    else:
        fig4()
