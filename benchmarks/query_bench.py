"""Paper Fig. 4: query rate (edges returned/s) vs queried-vertex degree.

Protocol mirrors §IV-B: ingest a large power-law graph + degree table
(D4M 2.0 schema, 8 ingestors), pick vertices with out/in degree near
{1, 10, 100, 1000} via the degree table, run the four query types —
single-vertex row (SVR), single-vertex column (SVC), multi-vertex row
(MVR, 5 vertices), multi-vertex column (MVC) — and measure edges/s.
Column queries exercise the transpose-table routing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.data.graph500 import graph500_triples
from repro.db import EdgeSchema, NaiveTable, dbsetup


def build_graph(scale: int = 13, ingestors: int = 8, use_pallas: bool = False):
    server = dbsetup("querybench", num_shards=4,
                     capacity_per_shard=1 << 21, batch_cap=1 << 16,
                     id_capacity=1 << 22, use_pallas=use_pallas)
    g = EdgeSchema(server, "g")
    naive = NaiveTable("naive")
    for i in range(ingestors):
        r, c, v = graph500_triples(scale, 16, seed=300 + i)
        g.put_triple(r, c, v)
        naive.put_triple(r, c, v)
    return g, naive


def _measure(fn, reps: int) -> tuple:
    t0 = time.time()
    edges = 0
    for _ in range(reps):
        a = fn()
        edges += a.nnz()
    return edges, time.time() - t0


def fig4(scale: int = 13, degrees=(1, 10, 100, 1000), reps: int = 5):
    g, naive = build_graph(scale)
    rng = np.random.default_rng(0)
    rows = []
    for target in degrees:
        for kind, sel in (("out", "row"), ("in", "col")):
            vs = g.deg.vertices_with_degree(target, kind=kind)
            if len(vs) == 0:
                continue
            single = str(rng.choice(vs)) + ","
            multi = "".join(str(v) + "," for v in
                            rng.choice(vs, size=min(5, len(vs)),
                                       replace=False))
            for qname, q in (("SV", single), ("MV", multi)):
                if sel == "row":
                    fn = lambda q=q: g[q, :]
                    fn_n = lambda q=q: naive[q, :]
                else:
                    fn = lambda q=q: g[:, q]
                    fn_n = lambda q=q: naive[:, q]
                fn()  # warmup (compile)
                edges, wall = _measure(fn, reps)
                edges_n, wall_n = _measure(fn_n, max(reps // 5, 1))
                label = f"{qname}{'R' if sel == 'row' else 'C'}"
                rows.append({
                    "degree": target, "query": label,
                    "edges_returned": edges // reps,
                    "opt_edges_per_s": edges / wall,
                    "naive_edges_per_s": edges_n / wall_n if edges_n else 0.0,
                })
                print(f"deg~{target:>5} {label}: {edges // reps:>7,} edges "
                      f"opt={edges / wall:>12,.0f} e/s "
                      f"naive={(edges_n / wall_n if edges_n else 0):>12,.0f} e/s")
    return rows


if __name__ == "__main__":
    fig4()
