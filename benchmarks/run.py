"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = edges/s or the
figure-specific rate). Reduced sizes keep the whole suite CPU-friendly;
pass --full for the paper-scale grid.

  PYTHONPATH=src python -m benchmarks.run [--full] [--json out.json]

``--json`` additionally writes the rows as structured records (name, rate,
engine, shard count, entries/sec where applicable) so successive PRs can
diff performance trajectories mechanically. A benchmark that raises is
recorded under ``errors`` (the artifact stays complete and parseable) and
the process exits nonzero so CI flags the run.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str, **meta) -> None:
    """Record one benchmark row; ``meta`` (engine=, shards=, entries_per_s=,
    ...) rides into the --json artifact for mechanical perf diffing."""
    ROWS.append({"name": name, "us_per_call": us_per_call,
                 "derived": derived, **meta})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ------------------------------------------------------- Fig 3 (ingest)
def bench_fig3_ingest(full: bool) -> None:
    from .ingest_bench import run_naive, run_optimized
    ks = (1, 2, 4, 8, 16) if full else (1, 4, 16)
    scales = (10, 12, 14) if full else (10, 12)
    for scale in scales:
        for k in ks:
            opt = run_optimized(k, scale)
            nai = run_naive(k, scale)
            emit(f"fig3_ingest_opt_s{scale}_k{k}",
                 opt["wall_s"] * 1e6,
                 f"{opt['edges_per_s']:.0f} edges/s serial; "
                 f"{opt['parallel_edges_per_s']:.0f} projected-parallel",
                 engine=opt.get("engine", "lsm"), shards=k,
                 entries_per_s=opt["edges_per_s"])
            emit(f"fig3_ingest_naive_s{scale}_k{k}",
                 nai["wall_s"] * 1e6,
                 f"{nai['edges_per_s']:.0f} edges/s (single stream, "
                 f"no partitioning)",
                 engine="naive", shards=1,
                 entries_per_s=nai["edges_per_s"])


def bench_fig3_batch_knob(full: bool) -> None:
    from .ingest_bench import batch_sweep
    budgets = (50_000, 200_000, 500_000, 2_000_000) if full \
        else (100_000, 500_000)
    for row in batch_sweep(scale=11, k=4, budgets=budgets):
        emit(f"fig3_batch_{row['char_budget']}", 0.0,
             f"{row['edges_per_s']:.0f} edges/s",
             engine="lsm", shards=4, entries_per_s=row["edges_per_s"])


def bench_fig3_straggler(full: bool) -> None:
    from .ingest_bench import run_optimized
    base = run_optimized(4, 11)
    steal = run_optimized(4, 11, steal=True)
    emit("fig3_straggler_worksteal", steal["wall_s"] * 1e6,
         f"{steal['edges_per_s']:.0f} edges/s vs {base['edges_per_s']:.0f} push",
         engine="lsm", shards=4, entries_per_s=steal["edges_per_s"])


# ------------------------------------------- engine A/B (LSM vs single)
def bench_engine_compare(full: bool) -> None:
    from .ingest_bench import engine_compare
    eps = 1 << 18 if full else 1 << 15
    mem = max(1 << 12, min(1 << 15, eps // 8))
    res = engine_compare(entries_per_shard=eps, shards=2,
                         batch=max(1 << 10, mem // 2), memtable=mem)
    for engine, r in res["engines"].items():
        emit(f"engine_{engine}_ingest_{eps}", r["ingest_wall_s"] * 1e6,
             f"{r['entries_per_s']:.0f} entries/s",
             engine=engine, shards=2, entries_per_s=r["entries_per_s"])
        emit(f"engine_{engine}_query_{eps}", r["query_wall_s"] * 1e6,
             f"{r['queries_per_s']:.0f} queries/s "
             f"flushed_on_read={r['flushed_on_read']}",
             engine=engine, shards=2)
    emit("engine_lsm_speedup", 0.0,
         f"{res['lsm_ingest_speedup']:.2f}x ingest vs single-run",
         engine="lsm", shards=2)


# -------------------------------------------------------- Fig 4 (query)
def bench_fig4_query(full: bool) -> None:
    from .query_bench import fig4
    rows = fig4(scale=13 if full else 11,
                degrees=(1, 10, 100, 1000) if full else (1, 10, 100),
                reps=5 if full else 3)
    for r in rows:
        emit(f"fig4_{r['query']}_deg{r['degree']}", 0.0,
             f"{r['opt_edges_per_s']:.0f} edges/s "
             f"(naive {r['naive_edges_per_s']:.0f})",
             engine="lsm", entries_per_s=r["opt_edges_per_s"])


# ------------------------------------- fused vs per-run LSM point reads
# NOTE: neither query bench writes BENCH_query.json here — that file at
# the repo root is the COMMITTED bench-gate baseline, regenerated only
# deliberately via `python -m benchmarks.query_bench --fused-compare
# --scan-compare --out BENCH_query.json` (a partial overwrite from an
# `--only` run would silently drop the other section from the gate).
# The speedup ratios still ride into --json via the emitted row meta.
def bench_query_fused(full: bool) -> None:
    """Read-path A/B: the fused single-dispatch query vs one bloom-gated
    launch per resident run."""
    from .query_bench import fused_read_compare
    res = fused_read_compare(reps=200 if full else 100)
    for r in res["rows"]:
        tag = "lvl" if r["with_levels"] else "l0"
        emit(f"query_fused_{tag}_runs{r['resident_runs_per_shard']}",
             r["fused_us_per_query"],
             f"{r['fused_speedup']:.2f}x vs per-run "
             f"({r['per_run_us_per_query']:.0f}us)",
             engine="lsm", shards=2,
             fused_speedup=r["fused_speedup"])


# ------------------------------- fused range scans vs point expansion
def bench_query_scan(full: bool) -> None:
    """Range-scan A/B: one fused fence-to-fence dispatch per shard vs
    expanding the range selector into an id list of point queries."""
    from .query_bench import scan_read_compare
    res = scan_read_compare(reps=50 if full else 20)
    for r in res["scan_rows"]:
        emit(f"query_scan_len{r['range_len']}", r["scan_us"],
             f"{r['scan_speedup']:.2f}x vs point expansion "
             f"({r['point_expansion_us']:.0f}us)",
             engine="lsm", shards=2, scan_speedup=r["scan_speedup"])


# ------------------------------------------- DB micro (compiled paths)
def bench_db_micro(full: bool) -> None:
    from repro.db.kvstore import ShardedTable

    n = 1 << 18
    for engine in ("single", "lsm"):
        store = ShardedTable(f"micro_{engine}", num_shards=1,
                             capacity_per_shard=n * 2, batch_cap=n,
                             id_capacity=1 << 22, use_pallas=False,
                             engine=engine, memtable_cap=n)
        rng = np.random.default_rng(0)
        rows = rng.integers(0, 1 << 22, n).astype(np.int32)
        cols = rng.integers(0, 1 << 16, n).astype(np.int32)
        vals = rng.normal(size=n).astype(np.float32)
        store.warmup()
        t0 = time.time()
        store.insert(rows, cols, vals)
        store.flush()
        dt = time.time() - t0
        emit(f"db_minor_compaction_262k_{engine}", dt * 1e6,
             f"{n / dt:.0f} triples/s", engine=engine, shards=1,
             entries_per_s=n / dt)

        q = rng.choice(rows, 4096).astype(np.int32)
        store.query_rows(q[:16])  # warmup
        t0 = time.time()
        store.query_rows(q)
        dt = time.time() - t0
        emit(f"db_rank_query_4096_{engine}", dt * 1e6,
             f"{4096 / dt:.0f} queries/s", engine=engine, shards=1)


# ------------------------------------------------- roofline (from dry-run)
def bench_roofline_summary(full: bool) -> None:
    import os
    from .roofline import load_records
    if not os.path.isdir("experiments/dryrun"):
        print("# roofline: experiments/dryrun missing — run "
              "`python -m repro.launch.dryrun --all --mesh both "
              "--out experiments/dryrun` first")
        return
    recs = [r for r in load_records() if "error" not in r
            and "skipped" not in r]
    for r in recs:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
             f"bottleneck={r['bottleneck']} dominant={dom * 1e3:.1f}ms "
             f"useful={r['useful_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write structured rows to PATH")
    args = ap.parse_args()
    benches = {
        "fig3": bench_fig3_ingest,
        "fig3_batch": bench_fig3_batch_knob,
        "fig3_straggler": bench_fig3_straggler,
        "engine": bench_engine_compare,
        "fig4": bench_fig4_query,
        "query_fused": bench_query_fused,
        "query_scan": bench_query_scan,
        "db_micro": bench_db_micro,
        "roofline": bench_roofline_summary,
    }
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn(args.full)
        except Exception as exc:  # keep the artifact complete + parseable
            traceback.print_exc()
            failures.append({"bench": name, "error": repr(exc)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": ROWS, "full": args.full,
                       "errors": failures}, f, indent=1)
        print(f"# wrote {args.json}", flush=True)
    if failures:
        print(f"# {len(failures)} benchmark(s) FAILED: "
              + ", ".join(f["bench"] for f in failures), flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
