"""Render a p50/p99 latency table from the benchmark JSON artifacts.

Reads ``BENCH_ingest.json`` / ``BENCH_query.json`` (or fresh CI copies)
plus an optional registry dump (``--metrics``, written by
``ingest_bench --metrics-out``) and prints a markdown latency table —
appended to ``$GITHUB_STEP_SUMMARY`` when set, so every CI run shows the
tail-latency trajectory next to the bench gate. When the committed tail
baseline (``--tails``, default ``BENCH_tails.json``) exists, a tail
SLO-burn table rides along: per op family, how much of the headroom
between the committed baseline and the gate's red line this run burned
(the gate itself lives in ``benchmarks.gate``; this is the dashboard).

  PYTHONPATH=src python -m benchmarks.latency_report \
      --ingest fresh_ingest.json --query fresh_query.json \
      --metrics METRICS_ingest.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional


def _load(path: Optional[str]) -> Optional[dict]:
    if not path or not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt(x, scale=1.0) -> str:
    if x is None:
        return "—"
    v = x * scale
    return f"{v:,.2f}" if v < 100 else f"{v:,.0f}"


def bench_rows(ingest: Optional[dict], query: Optional[dict]) -> List[dict]:
    """One row per (op, variant) with p50/p99 in microseconds."""
    rows: List[dict] = []
    if ingest:
        for eng, rec in (ingest.get("engines") or {}).items():
            if "ingest_batch_p50_ms" in rec:
                rows.append({"op": "ingest batch", "variant": eng,
                             "p50_us": rec["ingest_batch_p50_ms"] * 1e3,
                             "p99_us": rec.get("ingest_batch_p99_ms",
                                               0) * 1e3})
            if "query_p50_ms" in rec:
                rows.append({"op": "point query (16-row)", "variant": eng,
                             "p50_us": rec["query_p50_ms"] * 1e3,
                             "p99_us": rec.get("query_p99_ms", 0) * 1e3})
    if query:
        for r in query.get("rows") or []:
            if "fused_p50_us" not in r:
                continue
            tag = (f"{r.get('resident_runs_per_shard', '?')} runs"
                   + ("+levels" if r.get("with_levels") else ""))
            rows.append({"op": f"point read ({tag})", "variant": "fused",
                         "p50_us": r["fused_p50_us"],
                         "p99_us": r["fused_p99_us"]})
            rows.append({"op": f"point read ({tag})", "variant": "per_run",
                         "p50_us": r["per_run_p50_us"],
                         "p99_us": r["per_run_p99_us"]})
        for r in query.get("scan_rows") or []:
            if "scan_p50_us" not in r:
                continue
            tag = f"len={r.get('range_len', '?')}"
            rows.append({"op": f"range scan ({tag})", "variant": "fused",
                         "p50_us": r["scan_p50_us"],
                         "p99_us": r["scan_p99_us"]})
            rows.append({"op": f"range scan ({tag})",
                         "variant": "point_expansion",
                         "p50_us": r["point_expansion_p50_us"],
                         "p99_us": r["point_expansion_p99_us"]})
    return rows


def metrics_rows(metrics: Optional[dict]) -> List[dict]:
    """Histogram series from a registry dump (``Registry.dump``) — one
    row per latency series, p50/p99 read straight from the snapshot."""
    rows: List[dict] = []
    for key, snap in sorted((metrics or {}).items()):
        # counters dump as scalars; histograms as dicts with top-level
        # p50/p99 (present only when count > 0)
        if not isinstance(snap, dict) or "p50" not in snap:
            continue
        rows.append({"op": key, "variant": f"n={snap['count']}",
                     "p50_us": snap["p50"] * 1e6,
                     "p99_us": snap.get("p99", 0) * 1e6})
    return rows


def slo_burn_rows(tail_base: Optional[dict], ingest: Optional[dict],
                  query: Optional[dict]) -> List[dict]:
    """SLO-burn per tail family: how much of the budget headroom between
    the committed baseline and the gate's red line
    (``max(base*(1+thr), base+noise)``) this run consumed. 0% = at or
    below baseline, 100% = exactly at the red line, >100% = the gate
    job goes red on the same numbers."""
    if not tail_base:
        return []
    from benchmarks.gate import compare_tails, extract_tail_ratios
    thr = float(tail_base.get("threshold", 0.5))
    rows, _ok = compare_tails(tail_base.get("tails") or {},
                              tail_base.get("noise_floor") or {},
                              extract_tail_ratios(ingest, query), thr)
    out = []
    for r in rows:
        if r["baseline"] is None or r["new"] is None:
            continue
        headroom = r["budget"] - r["baseline"]
        burn = (r["new"] - r["baseline"]) / headroom if headroom > 0 \
            else float("inf")
        out.append({"ratio": r["ratio"], "baseline": r["baseline"],
                    "new": r["new"], "budget": r["budget"],
                    "burn_pct": max(0.0, burn * 100.0),
                    "status": r["status"]})
    return out


def slo_markdown(rows: List[dict]) -> str:
    if not rows:
        return ""
    lines = ["## Tail SLO burn", "",
             "budget = max(baseline × (1+threshold), baseline + noise "
             "floor); burn 100% = at the gate's red line", "",
             "| ratio | baseline | new | budget | burn | status |",
             "|---|---|---|---|---|---|"]
    for r in rows:
        mark = "🔥" if r["status"] == "REGRESSED" else \
            ("⚠️" if r["burn_pct"] > 50 else "✅")
        lines.append(
            f"| {r['ratio']} | {r['baseline']:.1f}x | {r['new']:.1f}x | "
            f"{r['budget']:.1f}x | {r['burn_pct']:.0f}% | "
            f"{mark} {r['status']} |")
    return "\n".join(lines) + "\n"


def markdown(rows: List[dict], title: str) -> str:
    if not rows:
        return ""
    lines = [f"## {title}", "",
             "| op | variant | p50 (µs) | p99 (µs) |",
             "|---|---|---|---|"]
    for r in rows:
        lines.append(f"| {r['op']} | {r['variant']} | "
                     f"{_fmt(r['p50_us'])} | {_fmt(r['p99_us'])} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ingest", default="BENCH_ingest.json")
    ap.add_argument("--query", default="BENCH_query.json")
    ap.add_argument("--metrics", default=None,
                    help="registry dump from ingest_bench --metrics-out")
    ap.add_argument("--tails", default="BENCH_tails.json",
                    help="committed tail baseline — adds the SLO-burn "
                         "table (skipped when the file is absent)")
    args = ap.parse_args(argv)
    ingest, query = _load(args.ingest), _load(args.query)
    md = markdown(bench_rows(ingest, query), "Latency (p50/p99)")
    mmd = markdown(metrics_rows(_load(args.metrics)),
                   "Registry latency series")
    smd = slo_markdown(slo_burn_rows(_load(args.tails), ingest, query))
    out = "\n".join(s for s in (md, mmd, smd) if s)
    if not out:
        print("no latency fields found in the given artifacts")
        return 0
    print(out)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
