"""Paper Fig. 3: ingest rate (edges/s) vs #ingest processes and graph scale.

Protocol mirrors §IV-A: k SPMD ingestors each generate a Graph500
unpermuted power-law graph (scale s, degree 16) and ingest adjacency
triples simultaneously in ~500k-char batches; the optimized connector
(sorted tablets + routing + merge compaction) is compared against the
naive reference connector (the Matlab-D4M stand-in). CPU scales are
reduced vs the paper (12-18 -> 10-14); the shapes of the curves are the
reproduction target, not absolute rates.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

from repro.data.graph500 import graph500_triples
from repro.db.batching import batch_triples
from repro.db.kvstore import ShardedTable, shard_of
from repro.db.naive import NaiveTable
from repro.core.dictionary import StringDict
from repro.kernels.common import I32_MAX
from repro.obs import Histogram, default_registry
from repro.train.elastic import WorkQueue

import jax
import jax.numpy as jnp


def _prepare(k: int, scale: int, char_budget: int):
    """Per-ingestor batch lists (string triples already batched)."""
    per_ingestor = []
    for i in range(k):
        r, c, v = graph500_triples(scale, 16, seed=100 + i)
        per_ingestor.append(list(batch_triples(r, c, v, char_budget)))
    return per_ingestor


def run_optimized(k: int, scale: int, char_budget: int = 500_000,
                  use_pallas: bool = False, steal: bool = False,
                  engine: str = "lsm") -> dict:
    """k simulated SPMD ingestors submitting one ~500k-char batch each per
    step. One CPU executes the k ingestors' work SERIALLY, so the measured
    wall is Σ-of-workers; ``parallel_edges_per_s`` (= serial rate × k) is
    the perfect-SPMD projection the shard_map path realizes on a real mesh
    (each ingestor's batch is an independent route+append, flushes are
    per-shard local — no cross-worker serialization)."""
    batches = _prepare(k, scale, char_budget)
    total_edges = sum(sum(len(b[0]) for b in bl) for bl in batches)
    # size tablet capacity from the ACTUAL shard skew (unpermuted power-law
    # graphs pile the hubs into the low-id shard) — Accumulo pre-split
    # planning from a sample
    probe = StringDict()
    counts = np.zeros(k, np.int64)
    bmax = 1
    for bl in batches:
        for b in bl:
            ids = probe.encode(b[0])
            counts += np.bincount(shard_of(ids, k, 1 << 22), minlength=k)
            bmax = max(bmax, len(b[0]))
    cap = max(1 << 12, int(counts.max() * 1.3))
    bcap = 1 << (bmax - 1).bit_length()
    # single engine: bulk-load mode, memtable sized to the tablet -> O(1)
    # compactions total (repeated merges into one run are quadratic).
    # lsm engine: memtable stays batch-sized — leveling amortizes instead.
    mem = max(cap, 4 * bcap) if engine == "single" else max(4 * bcap, cap // 8)
    mk = lambda name: ShardedTable(
        name, num_shards=k, capacity_per_shard=cap, batch_cap=bcap,
        id_capacity=1 << 22, use_pallas=use_pallas, memtable_cap=mem,
        engine=engine)
    # warmup on a throwaway store: compiles append (dominant padded batch
    # shape) + the flush path; jit caches are module-level, so the timed
    # store reuses them
    warm = mk("bench_warm")
    warm.insert(np.zeros(bcap, np.int32), np.zeros(bcap, np.int32),
                np.ones(bcap, np.float32))
    warm.flush()
    store = mk("bench")
    keydict = StringDict()

    t0 = time.time()
    if steal:  # straggler-mitigation mode: batches pulled from a work queue
        flat = [b for bl in batches for b in bl]
        q = WorkQueue(flat)
        while not q.complete():
            for w in range(k):
                bid, b = q.claim(w)
                if bid is None:
                    continue
                rid = keydict.encode(b[0])
                cid = keydict.encode(b[1])
                store.insert(rid, cid, b[2])
                q.ack(bid)
    else:
        step = 0
        while any(step < len(bl) for bl in batches):
            for bl in batches:           # each ingestor submits its batch
                if step < len(bl):
                    store.insert(keydict.encode(bl[step][0]),
                                 keydict.encode(bl[step][1]),
                                 bl[step][2].astype(np.float32))
            step += 1
    store.flush()
    if store.engine == "lsm":
        store._runs.l0_rows.block_until_ready()
    else:
        store.tablets.rows.block_until_ready()
    wall = time.time() - t0
    return {"k": k, "scale": scale, "engine": engine, "edges": total_edges,
            "wall_s": wall, "edges_per_s": total_edges / wall,
            "parallel_edges_per_s": total_edges / wall * k,
            "nnz": store.nnz()}


def run_naive(k: int, scale: int, char_budget: int = 500_000) -> dict:
    batches = _prepare(k, scale, char_budget)
    total_edges = sum(sum(len(b[0]) for b in bl) for bl in batches)
    tab = NaiveTable("bench")
    t0 = time.time()
    step = 0
    while any(step < len(bl) for bl in batches):
        for bl in batches:
            if step < len(bl):
                tab.put_triple(*bl[step])
        step += 1
    wall = time.time() - t0
    return {"k": k, "scale": scale, "edges": total_edges, "wall_s": wall,
            "edges_per_s": total_edges / wall}


def fig3(ks=(1, 2, 4, 8, 16), scales=(10, 12, 14), char_budget=500_000):
    rows = []
    for scale in scales:
        for k in ks:
            opt = run_optimized(k, scale, char_budget)
            nai = run_naive(k, scale, char_budget)
            rows.append({
                "scale": scale, "k": k, "edges": opt["edges"],
                "opt_edges_per_s": opt["edges_per_s"],
                "naive_edges_per_s": nai["edges_per_s"],
                "speedup": opt["edges_per_s"] / nai["edges_per_s"],
            })
            print(f"scale={scale} k={k:2d} edges={opt['edges']:>9,} "
                  f"opt={opt['edges_per_s']:>12,.0f} e/s "
                  f"naive={nai['edges_per_s']:>12,.0f} e/s")
    return rows


def batch_sweep(scale=12, k=4, budgets=(50_000, 200_000, 500_000, 2_000_000)):
    """The paper's 500k-char batch knob (§V crossover discussion)."""
    rows = []
    for b in budgets:
        r = run_optimized(k, scale, char_budget=b)
        rows.append({"char_budget": b, "edges_per_s": r["edges_per_s"]})
        print(f"budget={b:>9,} -> {r['edges_per_s']:>12,.0f} e/s")
    return rows


def engine_compare(entries_per_shard: int = 1 << 18, shards: int = 2,
                   batch: int = 1 << 14, memtable: int = 1 << 15,
                   n_queries: int = 2048, seed: int = 0,
                   repeats: int = 1) -> dict:
    """A/B the storage engines on identical int-triple streams.

    Demonstrates the LSM claim: flush cost scales with MEMTABLE size, not
    table capacity — the single-run engine re-merges the whole O(capacity)
    tablet on every memtable fill, so its ingest rate decays as the table
    grows, while the LSM engine's minor compactions stay O(memtable) with
    amortized leveling. The query phase measures point reads and verifies
    the LSM path never flushes (memtable untouched).

    ``repeats`` interleaves that many (single, lsm) ingest runs — fresh
    store each — and reports the MEDIAN per-repeat lsm/single wall ratio:
    shared-runner load hits both engines of a repeat pair alike, so the
    ratio the CI bench gate tracks stays stable even when absolute walls
    swing. Per-engine rates report the best wall (one-sided noise
    filter). The same repeats double as the tail NOISE FLOOR probe: each
    repeat yields one p99/p50 amplification per (engine, op) family —
    ingest from that repeat's store histograms, query from a per-round
    sampling pass — and the max-min spread across repeats lands in
    ``tail_noise``, which the CI gate uses as the jitter allowance when
    gating tail ratios (see ``benchmarks.gate.compare_tails``).

    A final query-batch sweep (64..4096 ids) times the FIRST call at each
    size — the one-shot serving semantics ``queries_per_s`` has always
    used — for the LSM tiled fused path, its per-run baseline, AND the
    legacy engine (steady-state rates ride along as advisory columns);
    ``lsm_query_speedup`` — the WORST lsm/single ratio across the sweep —
    is the large-batch read claim the CI gate tracks (pre-tiling, batches
    past ``fused_q_limit`` fell back to one launch per resident run and
    lost ~6x to the legacy engine even before its per-size retrace cost).
    """
    id_cap = 1 << 22
    total = entries_per_shard * shards
    cap = int(entries_per_shard * 1.25)
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, id_cap, total).astype(np.int32)
    cols = rng.integers(0, 1 << 16, total).astype(np.int32)
    vals = rng.normal(size=total).astype(np.float32)
    out = {"config": {"entries_per_shard": entries_per_shard,
                      "shards": shards, "batch": batch,
                      "memtable": memtable, "n_queries": n_queries,
                      "repeats": repeats},
           "engines": {}}
    q = rng.choice(rows, n_queries).astype(np.int32)

    def mk(engine, name):
        return ShardedTable(
            name, num_shards=shards, capacity_per_shard=cap,
            batch_cap=batch, id_capacity=id_cap, memtable_cap=memtable,
            engine=engine)

    # ---- phase 1: interleaved ingest timing (single/lsm back-to-back
    # within each repeat, so load noise cancels in the per-repeat ratio)
    walls = {"single": [], "lsm": []}
    stores = {}
    for engine in ("single", "lsm"):
        warm = mk(engine, f"warm_{engine}")  # compile appends off-clock
        warm.insert(rows[:batch], cols[:batch], vals[:batch])
        warm.flush()
    for rep in range(max(repeats, 1)):
        for engine in ("single", "lsm"):
            store = mk(engine, f"cmp_{engine}_{rep}")
            store.warmup()  # compile flush + every compaction depth
            t0 = time.time()
            for i in range(0, total, batch):
                store.insert(rows[i:i + batch], cols[i:i + batch],
                             vals[i:i + batch])
            store.flush()
            walls[engine].append(time.time() - t0)
            stores[engine] = store
    ratios = sorted(s / l for s, l in zip(walls["single"], walls["lsm"]))

    # ---- phase 2: flush-cost probe + query phase per engine
    reg = default_registry()
    mem_pre_read = {}
    tail_noise: dict = {}

    def _amp(h):
        p = h.percentiles()
        return p["p99"] / p["p50"] if p["p50"] else None

    for engine in ("single", "lsm"):
        store = stores[engine]
        ingest_wall = min(walls[engine])
        # per-batch ingest latency percentiles, pooled across every repeat's
        # store (repro.obs histograms populated by ShardedTable.insert
        # during the timed phase — tail latency beside the throughput rows).
        # Per-repeat p99/p50 amps feed the tail noise floor.
        h_ing = Histogram(reg, "pooled_ingest", {})
        ing_amps = []
        for rep in range(max(repeats, 1)):
            h_rep = Histogram(reg, "rep_ingest", {})
            for h in reg.series("db_op_latency_s",
                                table=f"cmp_{engine}_{rep}", op="ingest"):
                h_rep.merge(h)
            h_ing.merge(h_rep)
            a = _amp(h_rep)
            if a:
                ing_amps.append(a)
        if ing_amps:
            tail_noise[f"{engine}_ingest_p99_over_p50"] = {
                "repeats": ing_amps,
                "spread": max(ing_amps) - min(ing_amps)}
        # explicit flush-cost probe at FULL table size: the single-run
        # engine pays O(capacity) to absorb one memtable, the LSM engine
        # O(memtable) — the core scaling claim, measured directly
        half = memtable // 2
        store.insert(rows[:half], cols[:half], vals[:half])
        t0 = time.time()
        store.flush()
        if engine == "lsm":
            store._runs.l0_rows.block_until_ready()
        else:
            store.tablets.rows.block_until_ready()
        flush_wall = time.time() - t0
        # leave fresh writes in the memtable so the query path must merge
        # memtable + runs (the no-flush read claim), then compile the
        # engine's STATIC serving shapes off-clock: the LSM fused path has
        # exactly two (point bucket + query tile) and the tile serves any
        # batch size; the legacy engine has no size-independent shape to
        # warm — its first read also absorbs the tail into the tablet
        store.insert(rows[:256], cols[:256], vals[:256])
        store.warm_reads()
        # per-call query latency sampling: repeated SMALL batches (the
        # tracked queries_per_s protocol lives in the sweep below and
        # stays one-shot) so p50/p99 reflect per-dispatch read latency.
        # One sampling round per repeat: reported percentiles pool every
        # round, per-round amps feed the tail noise floor.
        qb = 16
        store.query_rows(q[:qb])  # warm the small-batch jit off the clock
        h_q = Histogram(reg, "pooled_query", {})
        q_amps = []
        for _rnd in range(max(repeats, 1)):
            store._h_query.reset()
            for i in range(64):
                j = (i * qb) % max(n_queries - qb, 1)
                store.query_rows(q[j:j + qb])
            h_q.merge(store._h_query)
            a = _amp(store._h_query)
            if a:
                q_amps.append(a)
        if q_amps:
            tail_noise[f"{engine}_query_p99_over_p50"] = {
                "repeats": q_amps,
                "spread": max(q_amps) - min(q_amps)}
        lat_q = h_q.percentiles()
        mem_pre_read[engine] = store._mem_n.copy()
        out["engines"][engine] = {
            "ingest_wall_s": ingest_wall,
            "entries_per_s": total / ingest_wall,
            "ingest_batch_p50_ms": h_ing.quantile(0.50) * 1e3,
            "ingest_batch_p99_ms": h_ing.quantile(0.99) * 1e3,
            "flush_at_full_table_s": flush_wall,
            "query_p50_ms": lat_q["p50"] * 1e3,
            "query_p99_ms": lat_q["p99"] * 1e3,
        }
        print(f"engine={engine:6s} ingest={total / ingest_wall:>12,.0f} e/s "
              f"full-table flush={flush_wall * 1e3:>8.1f} ms")
    # ---- phase 3: query batch-size sweep — the tiled fused read claim.
    # Protocol: FIRST-CALL wall per batch size, the same one-shot
    # semantics the tracked ``queries_per_s`` has always had ("a fresh
    # batch size arrives at the serving process"). Each engine pays what
    # its architecture charges on that first call: the legacy engine's
    # query shape follows the batch, so every novel size retraces; the
    # per-run baseline additionally launches once per resident run; the
    # tiled fused path serves ANY size from the one tile shape
    # ``warm_reads()`` precompiled. Steady-state rates (best of 3 warm
    # repeats) ride along as advisory columns: on this multi-run mixed
    # state the single tablet's warm read stays ahead at large batches
    # (classic LSM read amplification) — the gated claim is the serving
    # trajectory, where shape-churn dominates, and the regression this
    # metric guards is the old per-run fallback losing ~6x even there.
    def timed(store, qq, reps=3):
        t0 = time.time()
        res = store.query_rows(qq)
        first = time.time() - t0
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            store.query_rows(qq)
            best = min(best, time.time() - t0)
        return first, best, res

    sweep = []
    q_pool = rng.choice(rows, max(4096, n_queries)).astype(np.int32)
    lsm_store = stores["lsm"]
    for size in sorted({64, 256, 1024, 2048, 4096} | {n_queries}):
        qq = q_pool[:size]
        s_first, s_steady, s_res = timed(stores["single"], qq)
        lsm_store.fused_reads = True
        f_first, f_steady, f_res = timed(lsm_store, qq)
        lsm_store.fused_reads = False
        p_first, p_steady, _ = timed(lsm_store, qq)
        lsm_store.fused_reads = True
        sweep.append({"batch": size,
                      "single_qps": size / s_first,
                      "lsm_qps": size / f_first,
                      "lsm_perrun_qps": size / p_first,
                      "lsm_vs_single": s_first / f_first,
                      "fused_vs_perrun": p_first / f_first,
                      "single_steady_qps": size / s_steady,
                      "lsm_steady_qps": size / f_steady,
                      "lsm_perrun_steady_qps": size / p_steady,
                      "lsm_vs_single_steady": s_steady / f_steady})
        if size == n_queries:
            for eng, first, res in (("single", s_first, s_res),
                                    ("lsm", f_first, f_res)):
                out["engines"][eng].update(
                    query_wall_s=first, queries_per_s=size / first,
                    query_hits=int(len(res[0])))
        print(f"query batch={size:5d} single={size / s_first:>10,.0f} q/s "
              f"lsm={size / f_first:>10,.0f} q/s "
              f"perrun={size / p_first:>10,.0f} q/s "
              f"lsm/single={s_first / f_first:.2f}x "
              f"fused/perrun={p_first / f_first:.2f}x "
              f"(steady lsm/single={s_steady / f_steady:.2f}x)")
    # serving reads must merge the memtable tail on-device, never flush
    # (the single engine absorbed its tail at warm_reads, off-clock)
    for engine in ("single", "lsm"):
        out["engines"][engine]["flushed_on_read"] = bool(
            (stores[engine]._mem_n != mem_pre_read[engine]).any())
        out["engines"][engine]["stats"] = stores[engine].engine_stats()
    out["query_sweep"] = sweep
    out["tail_noise"] = tail_noise
    # worst-case first-call ratio across the sweep: the gate metric — LSM
    # reads must beat the legacy engine at EVERY batch size it serves
    out["lsm_query_speedup"] = min(r["lsm_vs_single"] for r in sweep)

    # median of the per-repeat interleaved ratios (== best-wall ratio
    # when repeats == 1): the trajectory metric the CI bench gate tracks
    out["lsm_ingest_speedup"] = ratios[len(ratios) // 2]
    out["lsm_ingest_speedup_all"] = ratios
    print(f"LSM ingest speedup over single-run: "
          f"{out['lsm_ingest_speedup']:.2f}x "
          f"at {entries_per_shard:,} entries/shard "
          f"(median of {len(ratios)} interleaved repeats)")
    return out


def pair_ingest_advisory(entries_per_shard: int = 1 << 14, shards: int = 2,
                         batch: int = 1 << 12, memtable: int = 1 << 13,
                         seed: int = 5) -> dict:
    """Dual-ingest write-amplification advisory for transpose pairs: the
    same triple stream into a single table vs an engine-maintained pair
    (``transpose=True``). The pair writes every entry to BOTH sibling
    memtables (~2x device write amplification) but logs ONE pair-tagged
    WAL record per batch (1x log bytes, one fsync — NOT 2x). Advisory
    only, never gated: absolute walls on shared runners are noisy and the
    pair cost model is structural."""
    import os
    import tempfile

    id_cap = 1 << 22
    total = entries_per_shard * shards
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, id_cap, total).astype(np.int32)
    cols = rng.integers(0, id_cap, total).astype(np.int32)
    vals = rng.normal(size=total).astype(np.float32)
    reg = default_registry()
    out = {"config": {"entries_per_shard": entries_per_shard,
                      "shards": shards, "batch": batch,
                      "memtable": memtable}}
    with tempfile.TemporaryDirectory() as td:
        walls = {}

        def mk(name, transpose, wal):
            return ShardedTable(
                f"adv_{name}", num_shards=shards,
                capacity_per_shard=int(entries_per_shard * 2.5),
                batch_cap=batch, id_capacity=id_cap,
                memtable_cap=memtable, engine="lsm",
                wal_dir=os.path.join(td, wal) if wal else None,
                transpose=transpose)

        # off-clock warm pass per CONFIG (not just per store): both runs
        # must hit fully compiled paths or the first config eats every
        # first-compile cost and the ratio flips
        for name, transpose in (("single", False), ("pair", True)):
            warm = mk(f"warm_{name}", transpose, None)
            warm.warmup()
            for i in range(0, min(total, 4 * batch), batch):
                warm.insert(rows[i:i + batch], cols[i:i + batch],
                            vals[i:i + batch])
            warm.flush()
            warm.close()
        for name, transpose in (("single", False), ("pair", True)):
            st = mk(name, transpose, name)
            st.warmup()
            t0 = time.time()
            for i in range(0, total, batch):
                st.insert(rows[i:i + batch], cols[i:i + batch],
                          vals[i:i + batch])
            st.flush()
            walls[name] = time.time() - t0
            out[f"wal_bytes_{name}"] = sum(
                c.value for c in reg.series("wal_append_bytes", log=name))
            st.close()
    out.update({
        "ingest_s_single": walls["single"],
        "ingest_s_pair": walls["pair"],
        "pair_ingest_slowdown": walls["pair"] / walls["single"],
        "wal_write_amp": out["wal_bytes_pair"] / out["wal_bytes_single"],
    })
    print(f"pair ingest advisory: slowdown="
          f"{out['pair_ingest_slowdown']:.2f}x "
          f"wal_write_amp={out['wal_write_amp']:.2f}x "
          f"({total:,} entries)")
    return {"pair_ingest": out}


def zipf_skew_advisory(s: float, entries_per_shard: int = 1 << 14,
                       shards: int = 4, batch: int = 1 << 12,
                       memtable: int = 1 << 13, seed: int = 7) -> dict:
    """Skewed-ingest A/B: static hash routing vs dynamic tablets under a
    Zipf(s) row stream over a CONTIGUOUS hot range (unpermuted power-law
    keys pile into the low-id shard — the Fig. 3 graph500 shape, worst
    case for a fixed pre-split). The dynamic table runs
    ``maybe_rebalance()`` every few batches, splitting the hot range and
    spreading tablets across shards; the static table keeps the uniform
    map. Reports the HOT-SHARD serving rate (queries/s on a Zipf-drawn id
    batch, whose traffic the static map concentrates on one shard) and
    the routed load balance (max/mean per-shard share of a fresh Zipf
    window) for both, plus ``zipf_split_vs_static`` — the balance
    improvement ratio the CI gate can track once a baseline carries it.
    Advisory: single-host walls don't show the mesh-level win; the
    balance ratio is the structural claim."""
    id_cap = 1 << 22
    total = entries_per_shard * shards
    rng = np.random.default_rng(seed)
    rows = (rng.zipf(s, total) % id_cap).astype(np.int32)
    cols = rng.integers(0, 1 << 16, total).astype(np.int32)
    vals = np.ones(total, np.float32)
    cap = int(total * 1.25)  # static piles ~everything onto shard 0

    def mk(name, dynamic):
        return ShardedTable(name, num_shards=shards,
                            capacity_per_shard=cap, batch_cap=batch,
                            id_capacity=id_cap, memtable_cap=memtable,
                            engine="lsm", dynamic_tablets=dynamic)

    out = {"config": {"zipf_s": s, "entries_per_shard": entries_per_shard,
                      "shards": shards, "batch": batch,
                      "memtable": memtable}}
    walls, qps, balance = {}, {}, {}
    for name, dynamic in (("static", False), ("dynamic", True)):
        warm = mk(f"zwarm_{name}", dynamic)  # compile off-clock
        warm.warmup()
        warm.insert(rows[:batch], cols[:batch], vals[:batch])
        warm.flush()
        st = mk(f"zipf_{name}", dynamic)
        st.warmup()
        t0 = time.time()
        for step, i in enumerate(range(0, total, batch)):
            st.insert(rows[i:i + batch], cols[i:i + batch],
                      vals[i:i + batch])
            if dynamic and step % 4 == 3:
                st.maybe_rebalance()
        st.flush()
        st._runs.l0_rows.block_until_ready()
        walls[name] = time.time() - t0
        # hot-shard serving rate: Zipf-drawn query batches, first call
        # warmed off-clock then best-of-3 (per-call dispatch cost is the
        # signal; the static map funnels every dispatch to one shard)
        q = (rng.zipf(s, 2048) % id_cap).astype(np.int32)
        st.warm_reads()
        st.query_rows(q)
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            st.query_rows(q)
            best = min(best, time.time() - t0)
        qps[name] = len(q) / best
        fresh = (rng.zipf(s, 1 << 15) % id_cap).astype(np.int64)
        routed = (st.tablet_map.owner_of(fresh) if dynamic
                  else shard_of(fresh.astype(np.int32), shards, id_cap))
        per = np.bincount(routed, minlength=shards)
        balance[name] = float(per.max() / per.mean())
        if dynamic:
            out["tablets"] = st.tablet_map.to_manifest()
    out.update({
        "ingest_s_static": walls["static"],
        "ingest_s_dynamic": walls["dynamic"],
        "hot_queries_per_s_static": qps["static"],
        "hot_queries_per_s_dynamic": qps["dynamic"],
        "load_balance_static": balance["static"],
        "load_balance_dynamic": balance["dynamic"],
        "zipf_split_vs_static": balance["static"] / balance["dynamic"],
    })
    print(f"zipf(s={s}) advisory: balance static="
          f"{balance['static']:.2f} dynamic={balance['dynamic']:.2f} "
          f"({out['zipf_split_vs_static']:.2f}x better) "
          f"hot q/s static={qps['static']:>10,.0f} "
          f"dynamic={qps['dynamic']:>10,.0f}")
    return {"zipf": out}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast engine A/B + JSON artifact (CI mode)")
    ap.add_argument("--out", default="BENCH_ingest.json",
                    help="JSON output path for --smoke/--compare")
    ap.add_argument("--compare", action="store_true",
                    help="full-size engine A/B (2^18 entries/shard)")
    ap.add_argument("--entries-per-shard", type=int, default=None)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=1,
                    help="interleave N (single, lsm) ingest runs; the "
                         "reported lsm_ingest_speedup is the MEDIAN "
                         "per-repeat ratio (noise-robust CI gate metric)")
    ap.add_argument("--zipf", type=float, default=None, metavar="S",
                    help="also run the Zipf(S) skew A/B (static hash vs "
                         "dynamic tablets): hot-shard queries/s + routed "
                         "load balance, advisory zipf_split_vs_static "
                         "ratio in the JSON artifact")
    ap.add_argument("--metrics-out", default=None,
                    help="also dump the full repro.obs registry snapshot "
                         "(counters + latency histograms) as JSON")
    ap.add_argument("--bundle-out", default=None,
                    help="also write a debug bundle (zip: metrics + "
                         "Prometheus text + slow traces/flight recordings "
                         "+ bench result) — the CI diagnostic artifact")
    args = ap.parse_args()
    if args.smoke or args.compare:
        eps = args.entries_per_shard or (1 << 14 if args.smoke else 1 << 18)
        mem = max(1 << 12, min(1 << 15, eps // 8))
        result = engine_compare(entries_per_shard=eps, shards=args.shards,
                                batch=max(1 << 10, mem // 2), memtable=mem,
                                repeats=args.repeats)
        result.update(pair_ingest_advisory(entries_per_shard=min(eps, 1 << 14),
                                           shards=args.shards))
        if args.zipf:
            result.update(zipf_skew_advisory(args.zipf,
                                             entries_per_shard=min(eps,
                                                                   1 << 14)))
        result["mode"] = "smoke" if args.smoke else "compare"
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.out}")
        if args.metrics_out:
            default_registry().dump(args.metrics_out)
            print(f"wrote {args.metrics_out}")
        if args.bundle_out:
            from repro.obs.export import write_debug_bundle
            write_debug_bundle(args.bundle_out,
                               extra={"bench_result": result})
            print(f"wrote {args.bundle_out}")
        return
    fig3()
    batch_sweep()


if __name__ == "__main__":
    main()
