"""Paper Fig. 3: ingest rate (edges/s) vs #ingest processes and graph scale.

Protocol mirrors §IV-A: k SPMD ingestors each generate a Graph500
unpermuted power-law graph (scale s, degree 16) and ingest adjacency
triples simultaneously in ~500k-char batches; the optimized connector
(sorted tablets + routing + merge compaction) is compared against the
naive reference connector (the Matlab-D4M stand-in). CPU scales are
reduced vs the paper (12-18 -> 10-14); the shapes of the curves are the
reproduction target, not absolute rates.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.data.graph500 import graph500_triples
from repro.db.batching import batch_triples
from repro.db.kvstore import ShardedTable, shard_of
from repro.db.naive import NaiveTable
from repro.core.dictionary import StringDict
from repro.kernels.common import I32_MAX
from repro.train.elastic import WorkQueue

import jax
import jax.numpy as jnp


def _prepare(k: int, scale: int, char_budget: int):
    """Per-ingestor batch lists (string triples already batched)."""
    per_ingestor = []
    for i in range(k):
        r, c, v = graph500_triples(scale, 16, seed=100 + i)
        per_ingestor.append(list(batch_triples(r, c, v, char_budget)))
    return per_ingestor


def run_optimized(k: int, scale: int, char_budget: int = 500_000,
                  use_pallas: bool = False, steal: bool = False) -> dict:
    """k simulated SPMD ingestors submitting one ~500k-char batch each per
    step. One CPU executes the k ingestors' work SERIALLY, so the measured
    wall is Σ-of-workers; ``parallel_edges_per_s`` (= serial rate × k) is
    the perfect-SPMD projection the shard_map path realizes on a real mesh
    (each ingestor's batch is an independent route+append, flushes are
    per-shard local — no cross-worker serialization)."""
    batches = _prepare(k, scale, char_budget)
    total_edges = sum(sum(len(b[0]) for b in bl) for bl in batches)
    # size tablet capacity from the ACTUAL shard skew (unpermuted power-law
    # graphs pile the hubs into the low-id shard) — Accumulo pre-split
    # planning from a sample
    probe = StringDict()
    counts = np.zeros(k, np.int64)
    bmax = 1
    for bl in batches:
        for b in bl:
            ids = probe.encode(b[0])
            counts += np.bincount(shard_of(ids, k, 1 << 22), minlength=k)
            bmax = max(bmax, len(b[0]))
    cap = max(1 << 12, int(counts.max() * 1.3))
    bcap = 1 << (bmax - 1).bit_length()
    # bulk-load mode: memtable sized to the tablet -> O(1) compactions
    # total (merging into a single sorted run repeatedly is quadratic; real
    # LSM trees level for the same reason)
    store = ShardedTable("bench", num_shards=k, capacity_per_shard=cap,
                         batch_cap=bcap, id_capacity=1 << 22,
                         use_pallas=use_pallas,
                         memtable_cap=max(cap, 4 * bcap))
    keydict = StringDict()

    # warmup: compile append (at the dominant padded batch shape) AND the
    # minor-compaction path — excluded from timing
    store.insert(np.zeros(bcap, np.int32), np.zeros(bcap, np.int32),
                 np.ones(bcap, np.float32))
    store.flush()
    store.tablets = jax.tree.map(lambda x: x, store.tablets)  # keep warm state
    # reset contents after warmup
    from repro.db.kvstore import tablet_empty
    import jax as _jax, jax.numpy as _jnp
    store.tablets = _jax.tree.map(lambda *xs: _jnp.stack(xs),
                                  *[tablet_empty(store.cap)] * k)

    t0 = time.time()
    if steal:  # straggler-mitigation mode: batches pulled from a work queue
        flat = [b for bl in batches for b in bl]
        q = WorkQueue(flat)
        while not q.complete():
            for w in range(k):
                bid, b = q.claim(w)
                if bid is None:
                    continue
                rid = keydict.encode(b[0])
                cid = keydict.encode(b[1])
                store.insert(rid, cid, b[2])
                q.ack(bid)
    else:
        step = 0
        while any(step < len(bl) for bl in batches):
            for bl in batches:           # each ingestor submits its batch
                if step < len(bl):
                    store.insert(keydict.encode(bl[step][0]),
                                 keydict.encode(bl[step][1]),
                                 bl[step][2].astype(np.float32))
            step += 1
    store.flush()
    store.tablets.rows.block_until_ready()
    wall = time.time() - t0
    return {"k": k, "scale": scale, "edges": total_edges, "wall_s": wall,
            "edges_per_s": total_edges / wall,
            "parallel_edges_per_s": total_edges / wall * k,
            "nnz": store.nnz()}


def run_naive(k: int, scale: int, char_budget: int = 500_000) -> dict:
    batches = _prepare(k, scale, char_budget)
    total_edges = sum(sum(len(b[0]) for b in bl) for bl in batches)
    tab = NaiveTable("bench")
    t0 = time.time()
    step = 0
    while any(step < len(bl) for bl in batches):
        for bl in batches:
            if step < len(bl):
                tab.put_triple(*bl[step])
        step += 1
    wall = time.time() - t0
    return {"k": k, "scale": scale, "edges": total_edges, "wall_s": wall,
            "edges_per_s": total_edges / wall}


def fig3(ks=(1, 2, 4, 8, 16), scales=(10, 12, 14), char_budget=500_000):
    rows = []
    for scale in scales:
        for k in ks:
            opt = run_optimized(k, scale, char_budget)
            nai = run_naive(k, scale, char_budget)
            rows.append({
                "scale": scale, "k": k, "edges": opt["edges"],
                "opt_edges_per_s": opt["edges_per_s"],
                "naive_edges_per_s": nai["edges_per_s"],
                "speedup": opt["edges_per_s"] / nai["edges_per_s"],
            })
            print(f"scale={scale} k={k:2d} edges={opt['edges']:>9,} "
                  f"opt={opt['edges_per_s']:>12,.0f} e/s "
                  f"naive={nai['edges_per_s']:>12,.0f} e/s")
    return rows


def batch_sweep(scale=12, k=4, budgets=(50_000, 200_000, 500_000, 2_000_000)):
    """The paper's 500k-char batch knob (§V crossover discussion)."""
    rows = []
    for b in budgets:
        r = run_optimized(k, scale, char_budget=b)
        rows.append({"char_budget": b, "edges_per_s": r["edges_per_s"]})
        print(f"budget={b:>9,} -> {r['edges_per_s']:>12,.0f} e/s")
    return rows


if __name__ == "__main__":
    fig3()
    batch_sweep()
