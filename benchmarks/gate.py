"""CI bench-gate: fail the build when a tracked benchmark ratio regresses.

CI runs benchmarks on every push but — before this gate — never COMPARED
them, so the BENCH_* trajectory could regress silently. The gate re-runs
the smoke benchmarks, extracts the tracked speedup ratios from the fresh
JSON, and compares each against the baselines committed at the repo root:

  * ``fused_vs_per_run``   — fused single-dispatch point reads vs the
                             per-run baseline (min over BENCH_query rows)
  * ``scan_vs_point``      — fused range scans vs id-list point expansion
                             (min over scan rows with range_len >= 64)
  * ``colsel_vs_filter``   — transpose-routed column selectors vs the
                             O(nnz) full-scan-and-filter baseline (min
                             over colsel rows with range_len >= 64)
  * ``lsm_vs_single``      — LSM ingest vs the single-run engine
                             (BENCH_ingest ``lsm_ingest_speedup``)
  * ``query_lsm_vs_single`` — LSM tiled fused reads vs the single-run
                             engine, WORST queries_per_s ratio across the
                             query batch-size sweep (BENCH_ingest
                             ``lsm_query_speedup``)
  * ``zipf_split_vs_static`` — dynamic-tablet routed load balance vs the
                             static hash under a Zipf skew sweep
                             (BENCH_ingest ``zipf`` section; advisory
                             until a committed baseline carries it)

A tracked ratio may drop at most ``--threshold`` (default 20%) below its
committed baseline; any deeper drop exits nonzero. Ratios are used rather
than absolute latencies so shared-runner noise cancels out (both sides of
each A/B run on the same machine in the same process).

Tail latencies (p99/p50 amplification per op family) are gated too, once
a tail baseline is committed at ``--tail-baseline`` (default
``BENCH_tails.json``). A fresh tail may exceed its baseline by the tail
threshold OR by the measured noise floor, whichever is larger::

    budget = max(base * (1 + tail_threshold), base + noise_floor[name])

The noise floor comes from ``ingest_bench --repeats N``: each repeat
interleaves a full (single, lsm) ingest + query-sampling pass, and the
max-min spread of the per-repeat p99/p50 amplifications is what
shared-runner jitter alone does to the tail — a regression must clear
that bar before it reds the gate. Without a committed tail baseline the
tail table stays advisory (bootstrap mode, as before). Regenerate the
baseline with ``--write-tail-baseline`` after an intentional tail change.

Usage (CI and local are the same invocation):

  PYTHONPATH=src python -m benchmarks.ingest_bench --smoke --repeats 5 \
      --out fresh_ingest.json
  PYTHONPATH=src python -m benchmarks.query_bench --fused-compare --scan-compare \
      --reps 50 --out fresh_query.json
  PYTHONPATH=src python -m benchmarks.gate \
      --baseline-ingest BENCH_ingest.json --baseline-query BENCH_query.json \
      --new-ingest fresh_ingest.json --new-query fresh_query.json \
      --tail-baseline BENCH_tails.json

A markdown summary table is printed and, when ``$GITHUB_STEP_SUMMARY`` is
set (CI), appended there too.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

MIN_SCAN_LEN = 64   # acceptance floor: scan must win from this length up


def extract_ratios(ingest: Optional[dict],
                   query: Optional[dict]) -> Dict[str, float]:
    """Pull the tracked speedup ratios out of benchmark JSON artifacts.
    Missing files/sections simply contribute no ratio (the gate reports
    them as untracked rather than failing — lets baselines grow)."""
    out: Dict[str, float] = {}
    if query:
        rows = query.get("rows") or []
        speedups = [r["fused_speedup"] for r in rows
                    if "fused_speedup" in r]
        if speedups:
            out["fused_vs_per_run"] = min(speedups)
        scan_rows = query.get("scan_rows") or []
        scans = [r["scan_speedup"] for r in scan_rows
                 if r.get("range_len", 0) >= MIN_SCAN_LEN]
        if scans:
            out["scan_vs_point"] = min(scans)
        colsel_rows = query.get("colsel_rows") or []
        colsels = [r["colsel_speedup"] for r in colsel_rows
                   if r.get("range_len", 0) >= MIN_SCAN_LEN]
        if colsels:
            out["colsel_vs_filter"] = min(colsels)
    if ingest:
        if "lsm_ingest_speedup" in ingest:
            out["lsm_vs_single"] = float(ingest["lsm_ingest_speedup"])
        if "lsm_query_speedup" in ingest:
            out["query_lsm_vs_single"] = float(ingest["lsm_query_speedup"])
        zipf = ingest.get("zipf") or {}
        if "zipf_split_vs_static" in zipf:
            out["zipf_split_vs_static"] = float(zipf["zipf_split_vs_static"])
    return out


def extract_tail_ratios(ingest: Optional[dict],
                        query: Optional[dict]) -> Dict[str, float]:
    """Tail-latency ratios: p99/p50 amplification per op family. Gated
    against the committed tail baseline when one exists (see
    ``compare_tails``), advisory bootstrap otherwise. Higher = fatter
    tail."""
    out: Dict[str, float] = {}

    def amp(hi, lo):
        return hi / lo if lo else None

    if query:
        fused = [amp(r.get("fused_p99_us"), r.get("fused_p50_us"))
                 for r in (query.get("rows") or [])]
        fused = [a for a in fused if a]
        if fused:
            out["fused_read_p99_over_p50"] = max(fused)
        scans = [amp(r.get("scan_p99_us"), r.get("scan_p50_us"))
                 for r in (query.get("scan_rows") or [])]
        scans = [a for a in scans if a]
        if scans:
            out["scan_p99_over_p50"] = max(scans)
        colsels = [amp(r.get("colsel_p99_us"), r.get("colsel_p50_us"))
                   for r in (query.get("colsel_rows") or [])]
        colsels = [a for a in colsels if a]
        if colsels:
            out["colsel_p99_over_p50"] = max(colsels)
    if ingest:
        for eng, rec in (ingest.get("engines") or {}).items():
            a = amp(rec.get("ingest_batch_p99_ms"),
                    rec.get("ingest_batch_p50_ms"))
            if a:
                out[f"{eng}_ingest_p99_over_p50"] = a
            a = amp(rec.get("query_p99_ms"), rec.get("query_p50_ms"))
            if a:
                out[f"{eng}_query_p99_over_p50"] = a
    return out


def extract_tail_noise(ingest: Optional[dict]) -> Dict[str, float]:
    """Per-family tail noise floor (max-min spread of the per-repeat
    p99/p50 amplification) from an ingest artifact's ``tail_noise``
    section (written by ``ingest_bench --repeats N``). Families the
    bench doesn't repeat (query-bench read paths) get no floor and gate
    on the relative threshold alone."""
    out: Dict[str, float] = {}
    for name, rec in ((ingest or {}).get("tail_noise") or {}).items():
        if isinstance(rec, dict) and "spread" in rec:
            out[name] = float(rec["spread"])
    return out


def compare_tails(baseline: Dict[str, float], noise_floor: Dict[str, float],
                  new: Dict[str, float],
                  threshold: float = 0.5) -> Tuple[List[dict], bool]:
    """Gated tail compare: one row per p99/p50 family. A tail regresses
    when the fresh amplification exceeds
    ``max(base * (1 + threshold), base + noise_floor)`` — the noise floor
    (measured spread across interleaved bench repeats) keeps runner
    jitter from redding the gate, the relative threshold catches real
    tail blowups. One-sided: a SHRINKING tail is always green. Like
    ``compare``, a baseline-tracked family missing from the fresh run
    fails closed; a family only the fresh run reports stays advisory."""
    rows, ok = [], True
    for name in sorted(set(baseline) | set(new)):
        b, n = baseline.get(name), new.get(name)
        if b is None:
            rows.append({"ratio": name, "baseline": b, "new": n,
                         "budget": None, "status": "untracked"})
            continue
        budget = max(b * (1.0 + threshold), b + noise_floor.get(name, 0.0))
        if n is None:
            ok = False
            rows.append({"ratio": name, "baseline": b, "new": n,
                         "budget": budget, "status": "MISSING"})
            continue
        regressed = n > budget
        ok = ok and not regressed
        rows.append({"ratio": name, "baseline": b, "new": n,
                     "budget": budget,
                     "status": "REGRESSED" if regressed else "ok"})
    return rows, ok


def _fmt_tail(x) -> str:
    return "—" if x is None else f"{x:.1f}x"


def tail_markdown(baseline: Dict[str, float],
                  new: Dict[str, float]) -> str:
    """Markdown for the advisory (bootstrap) tail table — used only when
    no tail baseline is committed yet; empty string when neither side
    carries tail fields (old artifacts)."""
    names = sorted(set(baseline) | set(new))
    if not names:
        return ""
    lines = ["## Tail latency (advisory)",
             "p99/p50 amplification per op family; no committed "
             "`BENCH_tails.json` yet, so informational only — commit one "
             "(`gate --write-tail-baseline`) to arm the tail gate", "",
             "| ratio | baseline | new |",
             "|---|---|---|"]
    for name in names:
        lines.append(f"| {name} | {_fmt_tail(baseline.get(name))} | "
                     f"{_fmt_tail(new.get(name))} |")
    return "\n".join(lines) + "\n"


def tail_gate_markdown(rows: List[dict], threshold: float) -> str:
    """Markdown for the GATED tail table (committed baseline present)."""
    if not rows:
        return ""
    lines = ["## Tail latency gate",
             f"p99/p50 amplification per op family; fail above "
             f"max(baseline × {1.0 + threshold:.2f}, baseline + noise "
             f"floor)", "",
             "| ratio | baseline | new | budget | status |",
             "|---|---|---|---|---|"]
    for r in rows:
        mark = {"ok": "✅", "REGRESSED": "❌",
                "MISSING": "❌"}.get(r["status"], "➖")
        lines.append(f"| {r['ratio']} | {_fmt_tail(r['baseline'])} | "
                     f"{_fmt_tail(r['new'])} | {_fmt_tail(r['budget'])} | "
                     f"{mark} {r['status']} |")
    return "\n".join(lines) + "\n"


def compare(baseline: Dict[str, float], new: Dict[str, float],
            threshold: float = 0.2) -> Tuple[List[dict], bool]:
    """One row per tracked ratio; ``ok`` is False iff a ratio present in
    both sides dropped more than ``threshold`` below its baseline, OR a
    baseline-tracked ratio is absent from the fresh run (fail-closed: a
    change that makes a gated metric disappear — flag drift, empty bench
    section — must not pass as 'untracked'). A ratio only the fresh run
    tracks stays advisory, so baselines can grow."""
    rows, ok = [], True
    for name in sorted(set(baseline) | set(new)):
        b, n = baseline.get(name), new.get(name)
        if b is None:
            rows.append({"ratio": name, "baseline": b, "new": n,
                         "rel": None, "status": "untracked"})
            continue
        if n is None:
            ok = False
            rows.append({"ratio": name, "baseline": b, "new": n,
                         "rel": None, "status": "MISSING"})
            continue
        rel = n / b if b else float("inf")
        regressed = rel < 1.0 - threshold
        ok = ok and not regressed
        rows.append({"ratio": name, "baseline": b, "new": n, "rel": rel,
                     "status": "REGRESSED" if regressed else "ok"})
    return rows, ok


def markdown(rows: List[dict], threshold: float) -> str:
    def fmt(x):
        return "—" if x is None else f"{x:.2f}x"

    lines = ["## Bench gate",
             f"tracked speedup ratios; fail below "
             f"{(1.0 - threshold) * 100:.0f}% of baseline", "",
             "| ratio | baseline | new | new/baseline | status |",
             "|---|---|---|---|---|"]
    for r in rows:
        rel = "—" if r["rel"] is None else f"{r['rel']:.2f}"
        mark = {"ok": "✅", "REGRESSED": "❌",
                "MISSING": "❌"}.get(r["status"], "➖")
        lines.append(f"| {r['ratio']} | {fmt(r['baseline'])} | "
                     f"{fmt(r['new'])} | {rel} | {mark} {r['status']} |")
    return "\n".join(lines) + "\n"


def _load(path: Optional[str]) -> Optional[dict]:
    if not path or not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-ingest", default="BENCH_ingest.json")
    ap.add_argument("--baseline-query", default="BENCH_query.json")
    ap.add_argument("--new-ingest", required=True)
    ap.add_argument("--new-query", required=True)
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max allowed relative drop (0.2 = 20%%)")
    ap.add_argument("--tail-baseline", default="BENCH_tails.json",
                    help="committed tail baseline (tails + noise floor); "
                         "absent file = advisory tail table (bootstrap)")
    ap.add_argument("--tail-threshold", type=float, default=None,
                    help="max allowed relative tail growth; defaults to "
                         "the baseline file's threshold, else 0.5")
    ap.add_argument("--write-tail-baseline", metavar="PATH", default=None,
                    help="write a fresh tail baseline from the --new "
                         "artifacts (tails + tail_noise spreads) and exit")
    args = ap.parse_args(argv)
    new_ingest, new_query = _load(args.new_ingest), _load(args.new_query)
    new_tails = extract_tail_ratios(new_ingest, new_query)
    if args.write_tail_baseline:
        payload = {"threshold": args.tail_threshold
                   if args.tail_threshold is not None else 0.5,
                   "tails": new_tails,
                   "noise_floor": extract_tail_noise(new_ingest)}
        with open(args.write_tail_baseline, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote tail baseline {args.write_tail_baseline} "
              f"({len(new_tails)} families)")
        return 0
    baseline = extract_ratios(_load(args.baseline_ingest),
                              _load(args.baseline_query))
    new = extract_ratios(new_ingest, new_query)
    rows, ok = compare(baseline, new, args.threshold)
    md = markdown(rows, args.threshold)
    tail_base = _load(args.tail_baseline)
    tails_ok = True
    if tail_base is not None:
        tail_thr = args.tail_threshold if args.tail_threshold is not None \
            else float(tail_base.get("threshold", 0.5))
        t_rows, tails_ok = compare_tails(tail_base.get("tails") or {},
                                         tail_base.get("noise_floor") or {},
                                         new_tails, tail_thr)
        tail_md = tail_gate_markdown(t_rows, tail_thr)
    else:
        tail_md = tail_markdown(
            extract_tail_ratios(_load(args.baseline_ingest),
                                _load(args.baseline_query)), new_tails)
    if tail_md:
        md = md + "\n" + tail_md
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md)
    if not baseline and tail_base is None:
        print("no committed baselines found — gate is advisory this run")
        return 0
    failures = []
    if baseline and not ok:
        failures.append("tracked ratio regressed past threshold")
    if tail_base is not None and not tails_ok:
        failures.append("tail p99/p50 exceeded its SLO budget")
    if failures:
        print("bench gate FAILED: " + "; ".join(failures))
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
