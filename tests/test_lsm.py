"""LSM storage engine tests: combiner semantics across flush/compaction
boundaries, bloom/fence read path (no flush on reads), WAL crash recovery,
k-way Pallas merge, connector delete semantics, SPMD L0 ingest."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.db import DBserver, dbsetup, delete
from repro.db.kvstore import ShardedTable
from repro.db.lsm import WriteAheadLog, recover
from repro.db.lsm.bloom import bloom_build, bloom_maybe_contains
from repro.db.lsm.engine import plan_levels
from repro.kernels.common import I32_MAX
from repro.kernels.merge_rank import kway_merge
from repro.kernels.merge_rank.ref import merge_sorted_ref

COMBINE = {
    "last": lambda old, new: new,
    "sum": lambda old, new: old + new,
    "min": min,
    "max": max,
}


def oracle_apply(oracle, rows, cols, vals, combiner):
    for r, c, v in zip(rows, cols, vals):
        k = (int(r), int(c))
        oracle[k] = COMBINE[combiner](oracle[k], float(v)) if k in oracle \
            else float(v)


def tiny_lsm(combiner="last", **kw):
    cfg = dict(num_shards=2, capacity_per_shard=4096, batch_cap=512,
               id_capacity=1 << 10, combiner=combiner, memtable_cap=64,
               engine="lsm")
    cfg.update(kw)
    return ShardedTable("lsm_t", **cfg)


# ---------------------------------------------- combiners across boundaries
@pytest.mark.parametrize("combiner", ["last", "sum", "min", "max"])
def test_combiner_across_flush_and_compaction(combiner):
    """Duplicate keys land in the memtable, several L0 runs, AND deeper
    levels; the combined result must match a sequential oracle exactly."""
    st = tiny_lsm(combiner)
    rng = np.random.default_rng(7)
    oracle = {}
    for _ in range(40):  # 64-entry memtable -> many flushes + compactions
        n = 48
        r = rng.integers(0, 200, n).astype(np.int32)
        c = rng.integers(0, 4, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        st.insert(r, c, v)
        oracle_apply(oracle, r, c, v, combiner)
    stats = st.engine_stats()
    assert stats["flushes"] > 4 and stats["major_compactions"] >= 1
    sr, sc, sv = st.scan()
    got = {(int(a), int(b)): float(x) for a, b, x in zip(sr, sc, sv)}
    assert set(got) == set(oracle)
    for k in oracle:
        assert got[k] == pytest.approx(oracle[k], rel=1e-5), (combiner, k)
    # scan output is sorted lex by (row, col) within each shard range
    assert np.all(np.lexsort((sc, sr)) == np.arange(len(sr)))


def test_point_queries_never_flush():
    st = tiny_lsm("sum")
    rng = np.random.default_rng(3)
    oracle = {}
    for _ in range(10):
        r = rng.integers(0, 1 << 10, 40).astype(np.int32)
        c = rng.integers(0, 4, 40).astype(np.int32)
        v = rng.normal(size=40).astype(np.float32)
        st.insert(r, c, v)
        oracle_apply(oracle, r, c, v, "sum")
    assert st._mem_n.max() > 0, "test needs a non-empty memtable"
    mem_before = st._mem_n.copy()
    l0_before = st.engine_stats()["l0_used"]
    q = np.unique([k[0] for k in oracle])[:64].astype(np.int32)
    qr, qc, qv = st.query_rows(q)
    assert (st._mem_n == mem_before).all() and \
        st.engine_stats()["l0_used"] == l0_before, "read triggered a flush"
    want = {k: v for k, v in oracle.items() if k[0] in set(q.tolist())}
    got = {(int(a), int(b)): float(x) for a, b, x in zip(qr, qc, qv)}
    assert set(got) == set(want)
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-5)


def test_query_widens_past_max_return_lsm():
    st = tiny_lsm("last", memtable_cap=2048, num_shards=1)
    n = 600
    st.insert(np.full(n, 7, np.int32), np.arange(n, dtype=np.int32),
              np.ones(n, np.float32))
    st.flush()  # run-resident (fence path), not just memtable
    r, c, v = st.query_rows(np.asarray([7], np.int32), max_return=256)
    assert len(c) == n and set(c.tolist()) == set(range(n))


def test_bloom_skips_absent_rows():
    st = tiny_lsm("last")
    rng = np.random.default_rng(5)
    # two key populations far apart; flush everything into runs
    st.insert(rng.integers(0, 100, 60).astype(np.int32),
              rng.integers(0, 4, 60).astype(np.int32),
              rng.normal(size=60).astype(np.float32))
    st.flush()
    st.insert(rng.integers(400, 500, 60).astype(np.int32),
              rng.integers(0, 4, 60).astype(np.int32),
              rng.normal(size=60).astype(np.float32))
    st.flush()
    before = dict(st.engine_stats())
    r, c, v = st.query_rows(np.asarray([250, 251, 252], np.int32))
    after = st.engine_stats()
    assert len(r) == 0
    assert after["runs_skipped"] > before["runs_skipped"], \
        "bloom/range filters should skip runs for absent keys"


def test_bloom_unit_no_false_negatives():
    rng = np.random.default_rng(0)
    keys = rng.choice(1 << 20, 500, replace=False).astype(np.int32)
    cap = 1024
    rows = np.full(cap, I32_MAX, np.int32)
    rows[:500] = np.sort(keys)
    words = np.asarray(bloom_build(rows, 256))
    present = np.asarray(bloom_maybe_contains(words, keys))
    assert present.all(), "bloom false negative"
    absent = np.setdiff1d(rng.choice(1 << 20, 2000), keys)[:1000]
    fp = np.asarray(bloom_maybe_contains(words, absent.astype(np.int32)))
    assert fp.mean() < 0.25, f"false-positive rate {fp.mean():.2f} too high"


def test_plan_levels_geometry():
    caps = plan_levels(1 << 19, 1 << 14, l0_slots=4, fanout=4)
    assert caps[-1] >= 1 << 19  # deepest holds advertised capacity
    assert all(b > a for a, b in zip(caps, caps[1:]))
    assert caps[-1] >= 4 * (1 << 14) + sum(caps[:-1])  # merge always fits


def test_lsm_overflow_backpressure():
    st = ShardedTable("tiny", num_shards=1, capacity_per_shard=64,
                      batch_cap=64, id_capacity=1 << 10, engine="lsm")
    with pytest.raises(OverflowError):
        for i in range(4):
            st.insert(np.arange(64, dtype=np.int32) + 64 * i,
                      np.zeros(64, np.int32), np.ones(64, np.float32))
            st.flush()


# --------------------------------------------------------- k-way merge op
def test_kway_merge_matches_ref():
    rng = np.random.default_rng(9)
    runs = []
    for n, cap in [(100, 128), (50, 256), (200, 256), (10, 64), (77, 128)]:
        r = np.full(cap, I32_MAX, np.int32)
        c = np.full(cap, I32_MAX, np.int32)
        v = np.zeros(cap, np.float32)
        rr = np.sort(rng.integers(0, 500, n)).astype(np.int32)
        cc = rng.integers(0, 8, n).astype(np.int32)
        order = np.lexsort((cc, rr))
        r[:n], c[:n] = rr[order], cc[order]
        v[:n] = rng.normal(size=n)
        runs.append((r, c, v))
    # Pallas path (interpret on CPU) vs pairwise-reduced jnp reference
    mr, mc, mv = kway_merge([tuple(map(np.asarray, run)) for run in runs],
                            use_pallas=True, interpret=True)
    er, ec, ev = runs[0]
    for run in runs[1:]:
        er, ec, ev = merge_sorted_ref(er, ec, ev, *run)
    np.testing.assert_array_equal(np.asarray(mr), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(mc), np.asarray(ec))
    total = sum((np.asarray(r) != I32_MAX).sum() for r, _, _ in runs)
    valid = np.asarray(mr) != I32_MAX
    assert valid.sum() == total
    # age order within equal-key groups: values of older runs come first
    np.testing.assert_allclose(np.asarray(mv)[valid], np.asarray(ev)[valid],
                               rtol=1e-6)


# ------------------------------------------------------------- durability
def test_wal_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    batches = []
    rng = np.random.default_rng(1)
    for _ in range(5):
        b = (rng.integers(0, 100, 20).astype(np.int32),
             rng.integers(0, 100, 20).astype(np.int32),
             rng.normal(size=20).astype(np.float32))
        wal.append(*b)
        batches.append(b)
    wal.close()
    got = list(WriteAheadLog.replay(path))
    assert len(got) == 5
    for (gr, gc, gv), (br, bc, bv) in zip(got, batches):
        np.testing.assert_array_equal(gr, br)
        np.testing.assert_array_equal(gc, bc)
        np.testing.assert_array_equal(gv, bv)
    # torn tail: chop the last record mid-payload -> replay drops ONLY it
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 37)
    got = list(WriteAheadLog.replay(path))
    assert len(got) == 4


def test_crash_recovery_snapshot_plus_wal(tmp_path):
    d = str(tmp_path / "db")
    st = ShardedTable("w", num_shards=2, capacity_per_shard=2048,
                      batch_cap=256, id_capacity=1 << 10, combiner="sum",
                      memtable_cap=64, engine="lsm", wal_dir=d)
    rng = np.random.default_rng(2)
    mk = lambda: (rng.integers(0, 1 << 10, 40).astype(np.int32),
                  rng.integers(0, 4, 40).astype(np.int32),
                  rng.normal(size=40).astype(np.float32))
    for _ in range(6):
        st.insert(*mk())
    st.checkpoint()
    for _ in range(4):  # post-snapshot writes live only in the WAL
        st.insert(*mk())
    want = st.scan()
    del st  # crash: all device state lost
    rec = recover(d)
    got = rec.scan()
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_allclose(got[2], want[2], rtol=1e-5)
    # recovered table stays writable + durable
    rec.insert(np.asarray([3], np.int32), np.asarray([1], np.int32),
               np.asarray([1.0], np.float32))
    assert rec.nnz() >= len(got[0])


def test_recovery_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        recover(str(tmp_path / "nope"))


def test_recovery_truncates_torn_tail_so_new_writes_survive(tmp_path):
    """Double-crash: recovery after a torn tail must truncate it, or every
    batch journaled after recovery is appended past the corrupt bytes and
    lost to the NEXT recovery."""
    d = str(tmp_path / "db")
    st = ShardedTable("w", num_shards=1, capacity_per_shard=2048,
                      batch_cap=256, id_capacity=1 << 10, combiner="last",
                      memtable_cap=64, engine="lsm", wal_dir=d)
    st.insert(np.asarray([1, 2], np.int32), np.asarray([0, 0], np.int32),
              np.asarray([1.0, 2.0], np.float32))
    st.checkpoint()
    st.insert(np.asarray([3], np.int32), np.asarray([0], np.int32),
              np.asarray([3.0], np.float32))
    del st
    wal = os.path.join(d, "wal.log")
    with open(wal, "r+b") as f:  # crash tore the last record mid-payload
        f.truncate(os.path.getsize(wal) - 5)
    rec = recover(d)  # row 3's torn record is (correctly) gone
    rec.insert(np.asarray([4], np.int32), np.asarray([0], np.int32),
               np.asarray([4.0], np.float32))
    del rec  # second crash, before any checkpoint
    rec2 = recover(d)
    rows = set(rec2.scan()[0].tolist())
    assert rows == {1, 2, 4}, rows  # row 4 must survive the second crash


def test_duplicate_query_ids_return_duplicate_results():
    """Legacy-engine parity: query_rows([x, x]) yields x's entries twice."""
    for engine in ("single", "lsm"):
        st = ShardedTable("dup", num_shards=1, capacity_per_shard=256,
                          batch_cap=64, id_capacity=1 << 10, engine=engine)
        st.insert(np.asarray([7, 7], np.int32), np.asarray([1, 2], np.int32),
                  np.asarray([1.0, 2.0], np.float32))
        r, c, v = st.query_rows(np.asarray([7, 7], np.int32))
        assert len(r) == 4, (engine, len(r))


# ------------------------------------------------------- connector delete
def test_delete_poisons_handle_and_frees_store():
    DB = dbsetup("deldb", dict(num_shards=2, capacity_per_shard=2048,
                               batch_cap=512, id_capacity=1 << 12))
    T = DB["t_del"]
    T.put_triple(np.asarray(["a", "b"], object), np.asarray(["x", "y"], object),
                 np.asarray([1.0, 2.0]))
    assert T.nnz() == 2
    delete(T)
    assert "t_del" not in DB.ls()
    with pytest.raises(RuntimeError):
        T.put_triple(np.asarray(["c"], object), np.asarray(["z"], object),
                     np.asarray([3.0]))
    with pytest.raises(RuntimeError):
        T["a,", :]
    with pytest.raises(RuntimeError):
        T.nnz()
    # re-binding the name creates a fresh, usable table
    T2 = DB["t_del"]
    assert T2.nnz() == 0


def test_legacy_engine_still_works_and_flushes_lazily():
    st = ShardedTable("legacy", num_shards=2, capacity_per_shard=2048,
                      batch_cap=256, id_capacity=1 << 10, engine="single")
    st.insert(np.asarray([1, 600], np.int32), np.asarray([0, 0], np.int32),
              np.asarray([1.0, 2.0], np.float32))
    st.flush()
    assert st._mem_n.max() == 0
    mem_before = st._mem_n.copy()
    r, c, v = st.query_rows(np.asarray([1], np.int32))
    assert len(r) == 1 and (st._mem_n == mem_before).all()


# ------------------------------------------------------------ SPMD L0 path
SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.db.spmd import (l0_stacked_empty, make_spmd_lsm_ingest_step,
                           make_spmd_lsm_compact_step,
                           make_spmd_lsm_query_step,
                           make_spmd_lsm_scan_step, stacked_empty)
from repro.kernels.common import I32_MAX

S, BCAP, IDCAP, SLOTS, CAP = 8, 128, 1 << 12, 3, 1 << 13
mesh = jax.make_mesh((S,), ("data",))
ingest = make_spmd_lsm_ingest_step(mesh, "data", S, IDCAP, combiner="sum")
compact = make_spmd_lsm_compact_step(mesh, "data", combiner="sum")
query = make_spmd_lsm_query_step(mesh, "data", combiner="sum",
                                 max_return=64)

l0 = l0_stacked_empty(S, SLOTS, S * BCAP)
level = stacked_empty(S, CAP)
sh3 = NamedSharding(mesh, P("data", None, None))
sh2 = NamedSharding(mesh, P("data", None))
sh1 = NamedSharding(mesh, P("data"))
l0 = jax.device_put(l0, type(l0)(rows=sh3, cols=sh3, vals=sh3, k=sh1))
level = jax.device_put(level, type(level)(rows=sh2, cols=sh2, vals=sh2, n=sh1))

rng = np.random.default_rng(0)
oracle = {}
for step in range(2 * SLOTS):
    br = np.full((S, BCAP), I32_MAX, np.int32)
    bc = np.full((S, BCAP), I32_MAX, np.int32)
    bv = np.zeros((S, BCAP), np.float32)
    for s in range(S):
        n = int(rng.integers(32, BCAP))
        r = rng.integers(0, IDCAP, n).astype(np.int32)
        c = rng.integers(0, 16, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        br[s, :n], bc[s, :n], bv[s, :n] = r, c, v
        for a, b, x in zip(r, c, v):
            oracle[(int(a), int(b))] = oracle.get((int(a), int(b)), 0.0) + float(x)
    l0 = ingest(l0, jax.device_put(jnp.asarray(br), sh2),
                jax.device_put(jnp.asarray(bc), sh2),
                jax.device_put(jnp.asarray(bv), sh2))
    if int(np.asarray(l0.k)[0]) == SLOTS:   # L0 full -> major compaction
        l0, level = compact(l0, level)
        assert int(np.asarray(level.n).max()) <= CAP

# fused read BEFORE the final compact: the L0 stack is non-empty, so the
# one-dispatch query must combine level + L0 runs on-device
QB = 16
all_keys = np.asarray(sorted({k[0] for k in oracle}), np.int64)
rng_q = np.random.default_rng(1)
qhost = np.full((S, QB), -1, np.int32)
want_q = {}
for s in range(S):
    lo, hi = s * IDCAP // S, (s + 1) * IDCAP // S
    mine = all_keys[(all_keys >= lo) & (all_keys < hi)]
    pick = (rng_q.choice(mine, size=min(QB - 2, len(mine)), replace=False)
            if len(mine) else np.empty(0, np.int64))
    qhost[s, :len(pick)] = np.sort(pick)
    for r in pick:
        for (rr, cc), v in oracle.items():
            if rr == r:
                want_q[(int(rr), int(cc))] = v
shq = NamedSharding(mesh, P("data", None))
qc, qv, qk = query(l0, level, jax.device_put(jnp.asarray(qhost), shq))
qc, qv, qk = np.asarray(qc), np.asarray(qv), np.asarray(qk)
got_q = {}
for s in range(S):
    for i in range(QB):
        if qhost[s, i] < 0:
            continue
        for j in np.nonzero(qk[s, i])[0]:
            got_q[(int(qhost[s, i]), int(qc[s, i, j]))] = float(qv[s, i, j])
assert set(got_q) == set(want_q), (len(got_q), len(want_q))
badq = [k for k in want_q if abs(got_q[k] - want_q[k]) > 1e-2]
assert not badq, badq[:5]
print("LSM-SPMD-QUERY-OK", len(got_q))

# query tiling: q_tile=8 splits the QB=16 batch into 2 tiles served by the
# same compiled step; outputs must match the untiled dispatch exactly
query_tiled = make_spmd_lsm_query_step(mesh, "data", combiner="sum",
                                       max_return=64, q_tile=8)
tc, tv, tk = query_tiled(l0, level, jax.device_put(jnp.asarray(qhost), shq))
np.testing.assert_array_equal(np.asarray(tk), qk)
np.testing.assert_array_equal(np.where(qk, np.asarray(tc), 0),
                              np.where(qk, qc, 0))
np.testing.assert_allclose(np.where(qk, np.asarray(tv), 0.0),
                           np.where(qk, qv, 0.0), rtol=1e-5, atol=1e-6)
print("LSM-SPMD-QUERY-TILED-OK")

# fused range scan (also BEFORE the final compact, so it must merge the
# level run + L0 stack on-device): a global [lo, hi) split into per-shard
# bounds; shards outside the range pass an empty interval
scan = make_spmd_lsm_scan_step(mesh, "data", combiner="sum", width=1024)
lo_g, hi_g = IDCAP // 4, IDCAP // 2
bounds = np.zeros((S, 2), np.int32)
for s in range(S):
    slo, shi = s * IDCAP // S, (s + 1) * IDCAP // S
    if max(lo_g, slo) < min(hi_g, shi):
        bounds[s] = (max(lo_g, slo), min(hi_g, shi))
sr, sc, sv, sk, scnt = scan(l0, level, jax.device_put(jnp.asarray(bounds), shq))
sr, sc, sv, sk = map(np.asarray, (sr, sc, sv, sk))
assert int(np.asarray(scnt).max()) <= 1024, "scan window overflow"
got_s = {}
for s in range(S):
    for j in np.nonzero(sk[s])[0]:
        got_s[(int(sr[s, j]), int(sc[s, j]))] = float(sv[s, j])
want_s = {k: v for k, v in oracle.items() if lo_g <= k[0] < hi_g}
assert set(got_s) == set(want_s), (len(got_s), len(want_s))
bads = [k for k in want_s if abs(got_s[k] - want_s[k]) > 1e-2]
assert not bads, bads[:5]
print("LSM-SPMD-SCAN-OK", len(got_s))

l0, level = compact(l0, level)
rows = np.asarray(level.rows); cols = np.asarray(level.cols)
vals = np.asarray(level.vals); ns = np.asarray(level.n)
got = {}
for s in range(S):
    for a, b, x in zip(rows[s, :ns[s]], cols[s, :ns[s]], vals[s, :ns[s]]):
        got[(int(a), int(b))] = float(x)
assert set(got) == set(oracle), (len(got), len(oracle))
bad = [k for k in oracle if abs(got[k] - oracle[k]) > 1e-2]
assert not bad, bad[:5]
print("LSM-SPMD-OK", len(got))
"""


@pytest.mark.slow
def test_spmd_lsm_ingest_and_compact():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         cwd=".", capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "LSM-SPMD-QUERY-OK" in out.stdout
    assert "LSM-SPMD-QUERY-TILED-OK" in out.stdout
    assert "LSM-SPMD-SCAN-OK" in out.stdout
    assert "LSM-SPMD-OK" in out.stdout
