"""Database layer tests: Listing-1 workflow, roundtrips, schema, batching,
combiners, overflow back-pressure, naive-baseline equivalence."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Assoc
from repro.data.graph500 import graph500_triples
from repro.db import (DBserver, EdgeSchema, NaiveTable, dbinit, dbsetup,
                      delete, put)
from repro.db.batching import batch_triples, triple_chars
from repro.db.kvstore import ShardedTable


def small_server(**kw):
    cfg = dict(num_shards=4, capacity_per_shard=4096, batch_cap=2048,
               id_capacity=1 << 16, use_pallas=True)
    cfg.update(kw)
    return dbsetup("testdb", cfg)


# ------------------------------------------------------- paper Listing 1
def test_listing1_workflow():
    dbinit()
    DB = small_server()
    Tedge = DB["my_Tedge", "my_TedgeT"]
    TedgeDeg = DB["my_TedgeDeg"]

    a = Assoc("e1,e1,e2,", "v1,v2,v1,", [1.0, 1.0, 1.0])
    put(Tedge, a)

    arow = Tedge["e1,", :]
    assert set(arow.col) == {"v1", "v2"}
    acol = Tedge[:, "v1,"]  # auto-routes to the transpose table
    assert set(acol.row) == {"e1", "e2"}
    assert set(acol.col) == {"v1"}

    delete(Tedge)
    delete(TedgeDeg)
    assert "my_Tedge" not in DB.ls()


def test_put_query_roundtrip_matches_assoc():
    DB = small_server()
    T = DB["t1"]
    rng = np.random.default_rng(3)
    n = 500
    rows = np.asarray([f"r{int(i):04d}" for i in rng.integers(0, 60, n)], object)
    cols = np.asarray([f"c{int(i):04d}" for i in rng.integers(0, 60, n)], object)
    vals = rng.integers(1, 100, n).astype(np.float64)
    a = Assoc(rows, cols, vals, func="last")
    T.put(a)
    assert T.nnz() == a.nnz()
    for key in ["r0000,", "r0031,", "r0005,r0007,"]:
        assert T[key, :].same_as(a[key, :]), key


def test_range_and_prefix_queries():
    DB = small_server()
    T = DB["t2"]
    T.put_triple(np.asarray(["alice", "bob", "carl", "dan"], object),
                 np.asarray(["x", "x", "x", "x"], object),
                 np.asarray([1.0, 2.0, 3.0, 4.0]))
    assert set(T["alice,:,carl,", :].row) == {"alice", "bob", "carl"}
    assert set(T["b*,", :].row) == {"bob"}
    assert T[:, :].nnz() == 4  # full scan


def test_string_values_roundtrip():
    DB = small_server()
    T = DB["t3"]
    T.put(Assoc("alice,", "bob,", "cited,"))
    out = T["alice,", :]
    r, c, v = out.triples()
    assert v[0] == "cited"


def test_last_wins_versioning():
    DB = small_server()
    T = DB["t4"]
    T.put_triple(np.asarray(["a"], object), np.asarray(["b"], object),
                 np.asarray([1.0]))
    T.put_triple(np.asarray(["a"], object), np.asarray(["b"], object),
                 np.asarray([9.0]))
    assert T.nnz() == 1
    _, _, v = T["a,", :].triples()
    assert v[0] == 9.0


def test_sum_combiner_table():
    store = ShardedTable("sumtab", num_shards=2, capacity_per_shard=256,
                         batch_cap=128, id_capacity=1 << 10, combiner="sum")
    for _ in range(3):
        store.insert(np.asarray([5, 5, 900], np.int32),
                     np.asarray([1, 1, 2], np.int32),
                     np.asarray([1.0, 2.0, 4.0], np.float32))
    r, c, v = store.query_rows(np.asarray([5, 900], np.int32))
    got = {(int(a), int(b)): float(x) for a, b, x in zip(r, c, v)}
    assert got == {(5, 1): 9.0, (900, 2): 12.0}


def test_overflow_backpressure():
    store = ShardedTable("tiny", num_shards=1, capacity_per_shard=64,
                         batch_cap=64, id_capacity=1 << 10)
    with pytest.raises(OverflowError):
        for i in range(4):
            store.insert(np.arange(64, dtype=np.int32) + 64 * i,
                         np.zeros(64, np.int32), np.ones(64, np.float32))
            store.flush()  # minor compaction surfaces the back-pressure


def test_query_widens_past_max_return():
    store = ShardedTable("wide", num_shards=1, capacity_per_shard=4096,
                         batch_cap=4096, id_capacity=1 << 10)
    n = 600  # one row with 600 entries > default max_return=256
    store.insert(np.full(n, 7, np.int32), np.arange(n, dtype=np.int32),
                 np.ones(n, np.float32))
    r, c, v = store.query_rows(np.asarray([7], np.int32), max_return=256)
    assert len(c) == n and set(c) == set(range(n))


# ------------------------------------------------------------- batching
def test_batching_respects_budget():
    rows = np.asarray(["r" * 50] * 100, object)
    cols = np.asarray(["c" * 49] * 100, object)
    vals = np.ones(100)
    batches = list(batch_triples(rows, cols, vals, char_budget=1000))
    assert sum(len(b[0]) for b in batches) == 100
    costs = triple_chars(rows, cols, vals)
    for br, _, _ in batches[:-1]:
        assert costs[: len(br)].sum() <= 1000 + costs[0]
    assert len(batches) > 5  # actually split


# ------------------------------------------------------- D4M 2.0 schema
def test_edge_schema_degrees():
    DB = small_server(capacity_per_shard=1 << 15, batch_cap=1 << 14)
    g = EdgeSchema(DB, "g")
    rows, cols, vals = graph500_triples(scale=6, edges_per_vertex=4, seed=1)
    g.put_triple(rows, cols, vals)
    # degree table must match a numpy bincount oracle over raw edges
    out_oracle = {}
    for r in rows:
        out_oracle[r] = out_oracle.get(r, 0) + 1
    deg = g.deg.degrees(":")
    dd = {k: v for (k, c), v in zip(zip(*deg.triples()[:2]), deg.triples()[2])
          if c == "OutDeg"}
    for k, v in out_oracle.items():
        assert dd[k] == v, k
    # degree-bucket vertex selection (paper Fig. 4 procedure)
    vs = g.deg.vertices_with_degree(max(out_oracle.values()), "out", tol=1.001)
    assert len(vs) >= 1
    # row query against the Assoc oracle (duplicate edges -> last-wins)
    a = Assoc(rows, cols, vals, func="last")
    probe = rows[0] + ","
    assert g[probe, :].same_as(a[probe, :])
    # column query via transpose table
    at = a.transpose()
    probe_c = cols[0] + ","
    assert g[:, probe_c].same_as(a[:, probe_c])


# ------------------------------------------------- naive baseline parity
def test_naive_matches_optimized():
    DB = small_server()
    T = DB["opt"]
    N = NaiveTable("naive")
    rows, cols, vals = graph500_triples(scale=5, edges_per_vertex=4, seed=2)
    a = Assoc(rows, cols, vals, func="last")
    T.put(a)
    N.put(a)
    for probe in [rows[0] + ",", rows[5] + ",", "v00000000,"]:
        assert T[probe, :].same_as(N[probe, :]), probe


# ------------------------------------------------------ property tests
keys = st.lists(st.integers(0, 30), min_size=1, max_size=40)


@settings(max_examples=25, deadline=None)
@given(keys, keys, st.integers(0, 2 ** 31 - 1))
def test_chunked_ingest_equals_bulk(rs, cs, seed):
    """Splitting an ingest into arbitrary chunks must not change the table."""
    n = min(len(rs), len(cs))
    rows = np.asarray([f"r{i}" for i in rs[:n]], object)
    cols = np.asarray([f"c{i}" for i in cs[:n]], object)
    vals = np.arange(1, n + 1).astype(np.float64)
    # last-wins oracle
    a = Assoc(rows, cols, vals, func="last")
    DB = small_server()
    T = DB["chunked"]
    rng = np.random.default_rng(seed)
    splits = np.sort(rng.integers(0, n + 1, 3))
    prev = 0
    for s in list(splits) + [n]:
        if s > prev:
            T.put_triple(rows[prev:s], cols[prev:s], vals[prev:s])
        prev = s
    assert T[:, :].same_as(a)
