"""Fault tolerance: checkpoint/restart, elastic resharding, dead-ingestor
re-routing, work-stealing straggler mitigation, gradient compression."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint
from repro.train.compress import (CompressConfig, compress_with_feedback,
                                  int8_compress, int8_decompress,
                                  topk_compress, topk_decompress,
                                  wire_bytes, zero_residual)
from repro.train.elastic import WorkQueue, reassign_dead_ingestor


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    checkpoint.save(str(tmp_path), 7, tree)
    got, manifest = checkpoint.restore(str(tmp_path), tree)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_keep_last_k(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in range(5):
        checkpoint.save(str(tmp_path), s, tree, keep_last_k=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert checkpoint.latest_step(str(tmp_path)) == 4


def test_restart_after_kill_resumes(tmp_path):
    """Simulated node failure: train 3 steps, 'crash', restart, resume —
    the resumed trajectory must equal an uninterrupted 6-step run."""
    from repro.configs import get_reduced
    from repro.models import build, init_params
    from repro.train import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    model = build(get_reduced("smollm-135m"))
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(model, opt_cfg))
    rng = np.random.default_rng(0)
    batches = [
        {"tokens": jnp.asarray(rng.integers(1, 500, (2, 32)), jnp.int32)}
        for _ in range(6)]

    params = init_params(model.param_specs, jax.random.key(0))
    opt = adamw_init(params, opt_cfg)
    ref = (params, opt)
    for b in batches:
        p, o, _ = step(ref[0], ref[1], b)
        ref = (p, o)

    # interrupted run: checkpoint at step 3, restart from disk
    params = init_params(model.param_specs, jax.random.key(0))
    opt = adamw_init(params, opt_cfg)
    for b in batches[:3]:
        params, opt, _ = step(params, opt, b)
    checkpoint.save(str(tmp_path), 3, {"params": params, "opt": opt})
    del params, opt  # "crash"
    state, _ = checkpoint.restore(
        str(tmp_path), {"params": ref[0], "opt": ref[1]})
    params, opt = state["params"], state["opt"]
    for b in batches[3:]:
        params, opt, _ = step(params, opt, b)

    for got, want in zip(jax.tree.leaves(params), jax.tree.leaves(ref[0])):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_reduced
from repro.models import build, init_params, sharding_tree
from repro.models.spec import ShardingRules
from repro.train import checkpoint
from repro.compat import make_mesh_auto

model = build(get_reduced("smollm-135m"))
params = init_params(model.param_specs, jax.random.key(1))
ckpt = os.environ["CKPT_DIR"]
checkpoint.save(ckpt, 1, params)

# restore onto DP=8 then DP=4 ("node failure -> shrink") meshes
for dp in (8, 4):
    mesh = make_mesh_auto((dp, 1), ("data", "model"),
                          devices=jax.devices()[:dp])
    rules = ShardingRules(batch=("data",), fsdp="data")
    sh = sharding_tree(model.param_specs, rules, mesh)
    got, _ = checkpoint.restore(ckpt, params, shardings=sh)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC-OK", dp)
"""


def test_elastic_restore_across_mesh_sizes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["CKPT_DIR"] = str(tmp_path)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC-OK 8" in out.stdout and "ELASTIC-OK 4" in out.stdout


# ------------------------------------------------------- data-plane faults
def test_dead_ingestor_rerouting():
    """Dropping a shard's split point must keep every key owned."""
    from repro.db.kvstore import shard_of
    sp = np.asarray([100, 200, 300], np.int32)  # 4 shards
    new_sp = reassign_dead_ingestor(sp, dead=1)
    assert len(new_sp) == 2
    keys = np.arange(0, 400, 7, dtype=np.int32)
    owners = np.searchsorted(new_sp, keys, side="right")
    assert owners.max() < 3 and owners.min() >= 0


def test_work_stealing_survives_dead_worker():
    q = WorkQueue(list(range(10)), timeout_batches=3)
    # worker 0 claims and dies; workers 1-2 finish everything
    bid0, _ = q.claim(0)
    while not q.complete():
        for w in (1, 2):
            bid, _ = q.claim(w)
            if bid is not None:
                q.ack(bid)
        if q.clock > 200:
            raise AssertionError("queue did not drain")
    assert bid0 in q.done  # re-queued and completed by someone else


# ------------------------------------------------------------ compression
@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compress_roundtrip_bounded_error(scheme):
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(300, 70)), jnp.float32)
    if scheme == "int8":
        payload, shape, n = int8_compress(g)
        d = int8_decompress(payload, shape, n)
        assert float(jnp.max(jnp.abs(d - g))) <= float(jnp.max(jnp.abs(g))) / 100
    else:
        payload, shape, n = topk_compress(g, 0.1)
        d = topk_decompress(payload, shape, n)
        kept = int((np.asarray(d) != 0).sum())
        assert kept == int(g.size * 0.1)


def test_error_feedback_converges():
    """EF compression must not bias a simple quadratic optimization."""
    w = jnp.asarray([5.0, -3.0, 2.0])
    target = jnp.asarray([1.0, 1.0, 1.0])
    cfg = CompressConfig(scheme="topk", topk_frac=0.34)  # keep 1 of 3
    residual = zero_residual({"w": w})
    params = {"w": w}
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        cg, residual = compress_with_feedback(grads, residual, cfg)
        params = {"w": params["w"] - 0.05 * cg["w"]}
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_wire_bytes_accounting():
    g = {"a": jnp.zeros((1000, 100))}
    raw, comp = wire_bytes(g, CompressConfig(scheme="int8"))
    assert raw == 400_000
    assert comp < raw / 3.5
