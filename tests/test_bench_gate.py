"""The CI bench-gate must go red on a synthetic >20% ratio regression and
stay green within the threshold (acceptance bar for the gate job), and its
markdown summary must land in $GITHUB_STEP_SUMMARY."""
import json

from benchmarks.gate import (compare, extract_ratios, extract_tail_ratios,
                             main, markdown, tail_markdown)

BASE_QUERY = {
    "rows": [{"fused_speedup": 1.8}, {"fused_speedup": 1.5}],
    "scan_rows": [{"range_len": 64, "scan_speedup": 2.2},
                  {"range_len": 1024, "scan_speedup": 9.0},
                  {"range_len": 8, "scan_speedup": 0.7}],  # below floor
}
BASE_INGEST = {"lsm_ingest_speedup": 1.4}


def test_extract_tracked_ratios():
    got = extract_ratios(BASE_INGEST, BASE_QUERY)
    assert got == {"fused_vs_per_run": 1.5,  # min over rows
                   "scan_vs_point": 2.2,     # min over rows >= 64
                   "lsm_vs_single": 1.4}


def test_green_within_threshold_red_past_it():
    base = extract_ratios(BASE_INGEST, BASE_QUERY)
    # 10% drop everywhere: inside the 20% budget -> green
    mild = {k: v * 0.9 for k, v in base.items()}
    rows, ok = compare(base, mild, threshold=0.2)
    assert ok and all(r["status"] == "ok" for r in rows)
    # one ratio drops 25% -> red, and only that row flags
    bad = dict(base)
    bad["scan_vs_point"] = base["scan_vs_point"] * 0.75
    rows, ok = compare(base, bad, threshold=0.2)
    assert not ok
    flags = {r["ratio"]: r["status"] for r in rows}
    assert flags["scan_vs_point"] == "REGRESSED"
    assert flags["fused_vs_per_run"] == "ok"
    # a NEW ratio the baseline doesn't track yet is advisory (baselines
    # can grow) ...
    grown = dict(base, brand_new_ratio=3.0)
    rows, ok = compare(base, grown, threshold=0.2)
    assert ok and {r["status"] for r in rows} == {"ok", "untracked"}
    # ... but a baseline-tracked ratio MISSING from the fresh run fails
    # closed (flag drift / empty bench section must not pass silently)
    rows, ok = compare(base, {k: v for k, v in base.items()
                              if k != "lsm_vs_single"}, threshold=0.2)
    assert not ok
    assert {r["ratio"]: r["status"] for r in rows}["lsm_vs_single"] \
        == "MISSING"


def test_main_exit_codes_and_step_summary(tmp_path, monkeypatch):
    bq = tmp_path / "bq.json"
    bi = tmp_path / "bi.json"
    bq.write_text(json.dumps(BASE_QUERY))
    bi.write_text(json.dumps(BASE_INGEST))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    argv_base = ["--baseline-ingest", str(bi), "--baseline-query", str(bq)]
    # identical fresh run -> green
    assert main(argv_base + ["--new-ingest", str(bi),
                             "--new-query", str(bq)]) == 0
    assert "Bench gate" in summary.read_text()
    # synthetic 25% regression on the scan ratio -> red
    worse = dict(BASE_QUERY)
    worse["scan_rows"] = [{"range_len": 64, "scan_speedup": 2.2 * 0.75},
                          {"range_len": 1024, "scan_speedup": 9.0}]
    wq = tmp_path / "wq.json"
    wq.write_text(json.dumps(worse))
    assert main(argv_base + ["--new-ingest", str(bi),
                             "--new-query", str(wq)]) == 1
    assert "REGRESSED" in summary.read_text()
    # no baselines at all -> advisory (repo bootstrap), green
    assert main(["--baseline-ingest", str(tmp_path / "none1.json"),
                 "--baseline-query", str(tmp_path / "none2.json"),
                 "--new-ingest", str(bi), "--new-query", str(bq)]) == 0


def test_tail_ratios_are_advisory_only(tmp_path, monkeypatch):
    """Tail-latency (p99/p50) ratios ride along in the summary but can
    NEVER turn the gate red — even a 100x tail blowup must exit 0 while
    still being visible in the advisory table."""
    ingest = {"lsm_ingest_speedup": 1.4,
              "engines": {"lsm": {"ingest_batch_p50_ms": 1.0,
                                  "ingest_batch_p99_ms": 8.0,
                                  "query_p50_ms": 0.5,
                                  "query_p99_ms": 2.0}}}
    query = {"rows": [{"fused_speedup": 1.5, "fused_p50_us": 100.0,
                       "fused_p99_us": 400.0}],
             "scan_rows": [{"range_len": 64, "scan_speedup": 2.2,
                            "scan_p50_us": 200.0, "scan_p99_us": 900.0}]}
    tails = extract_tail_ratios(ingest, query)
    assert tails == {"lsm_ingest_p99_over_p50": 8.0,
                     "lsm_query_p99_over_p50": 4.0,
                     "fused_read_p99_over_p50": 4.0,
                     "scan_p99_over_p50": 4.5}
    # old artifacts without tail fields -> no table at all
    assert extract_tail_ratios(BASE_INGEST, BASE_QUERY) == {}
    assert tail_markdown({}, {}) == ""
    # blow up every tail 100x in the fresh run; tracked ratios unchanged
    worse = json.loads(json.dumps(query))
    worse["rows"][0]["fused_p99_us"] *= 100
    worse["scan_rows"][0]["scan_p99_us"] *= 100
    bi, bq = tmp_path / "bi.json", tmp_path / "bq.json"
    wq = tmp_path / "wq.json"
    bi.write_text(json.dumps(ingest))
    bq.write_text(json.dumps(query))
    wq.write_text(json.dumps(worse))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert main(["--baseline-ingest", str(bi), "--baseline-query", str(bq),
                 "--new-ingest", str(bi), "--new-query", str(wq)]) == 0
    text = summary.read_text()
    assert "Tail latency (advisory)" in text
    assert "fused_read_p99_over_p50" in text


def test_markdown_table_shape():
    base = extract_ratios(BASE_INGEST, BASE_QUERY)
    rows, _ = compare(base, base)
    md = markdown(rows, 0.2)
    assert md.count("|") >= 5 * (len(rows) + 2)
    for name in base:
        assert name in md
