"""The CI bench-gate must go red on a synthetic >20% ratio regression and
stay green within the threshold (acceptance bar for the gate job), its
markdown summary must land in $GITHUB_STEP_SUMMARY, and the tail p99/p50
gate must respect the committed baseline + noise-floor budget (red past
it, green within it, advisory bootstrap without a baseline file)."""
import json

from benchmarks.gate import (compare, compare_tails, extract_ratios,
                             extract_tail_noise, extract_tail_ratios,
                             main, markdown, tail_gate_markdown,
                             tail_markdown)

BASE_QUERY = {
    "rows": [{"fused_speedup": 1.8}, {"fused_speedup": 1.5}],
    "scan_rows": [{"range_len": 64, "scan_speedup": 2.2},
                  {"range_len": 1024, "scan_speedup": 9.0},
                  {"range_len": 8, "scan_speedup": 0.7}],  # below floor
}
BASE_INGEST = {"lsm_ingest_speedup": 1.4}


def test_extract_tracked_ratios():
    got = extract_ratios(BASE_INGEST, BASE_QUERY)
    assert got == {"fused_vs_per_run": 1.5,  # min over rows
                   "scan_vs_point": 2.2,     # min over rows >= 64
                   "lsm_vs_single": 1.4}


def test_green_within_threshold_red_past_it():
    base = extract_ratios(BASE_INGEST, BASE_QUERY)
    # 10% drop everywhere: inside the 20% budget -> green
    mild = {k: v * 0.9 for k, v in base.items()}
    rows, ok = compare(base, mild, threshold=0.2)
    assert ok and all(r["status"] == "ok" for r in rows)
    # one ratio drops 25% -> red, and only that row flags
    bad = dict(base)
    bad["scan_vs_point"] = base["scan_vs_point"] * 0.75
    rows, ok = compare(base, bad, threshold=0.2)
    assert not ok
    flags = {r["ratio"]: r["status"] for r in rows}
    assert flags["scan_vs_point"] == "REGRESSED"
    assert flags["fused_vs_per_run"] == "ok"
    # a NEW ratio the baseline doesn't track yet is advisory (baselines
    # can grow) ...
    grown = dict(base, brand_new_ratio=3.0)
    rows, ok = compare(base, grown, threshold=0.2)
    assert ok and {r["status"] for r in rows} == {"ok", "untracked"}
    # ... but a baseline-tracked ratio MISSING from the fresh run fails
    # closed (flag drift / empty bench section must not pass silently)
    rows, ok = compare(base, {k: v for k, v in base.items()
                              if k != "lsm_vs_single"}, threshold=0.2)
    assert not ok
    assert {r["ratio"]: r["status"] for r in rows}["lsm_vs_single"] \
        == "MISSING"


def test_main_exit_codes_and_step_summary(tmp_path, monkeypatch):
    bq = tmp_path / "bq.json"
    bi = tmp_path / "bi.json"
    bq.write_text(json.dumps(BASE_QUERY))
    bi.write_text(json.dumps(BASE_INGEST))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    # point the tail baseline at a nonexistent file: this test exercises
    # the tracked-ratio gate alone (the repo root commits a real
    # BENCH_tails.json that would otherwise arm the tail gate)
    argv_base = ["--baseline-ingest", str(bi), "--baseline-query", str(bq),
                 "--tail-baseline", str(tmp_path / "no_tails.json")]
    # identical fresh run -> green
    assert main(argv_base + ["--new-ingest", str(bi),
                             "--new-query", str(bq)]) == 0
    assert "Bench gate" in summary.read_text()
    # synthetic 25% regression on the scan ratio -> red
    worse = dict(BASE_QUERY)
    worse["scan_rows"] = [{"range_len": 64, "scan_speedup": 2.2 * 0.75},
                          {"range_len": 1024, "scan_speedup": 9.0}]
    wq = tmp_path / "wq.json"
    wq.write_text(json.dumps(worse))
    assert main(argv_base + ["--new-ingest", str(bi),
                             "--new-query", str(wq)]) == 1
    assert "REGRESSED" in summary.read_text()
    # no baselines at all -> advisory (repo bootstrap), green
    assert main(["--baseline-ingest", str(tmp_path / "none1.json"),
                 "--baseline-query", str(tmp_path / "none2.json"),
                 "--tail-baseline", str(tmp_path / "no_tails.json"),
                 "--new-ingest", str(bi), "--new-query", str(bq)]) == 0


TAIL_INGEST = {"lsm_ingest_speedup": 1.4,
               "engines": {"lsm": {"ingest_batch_p50_ms": 1.0,
                                   "ingest_batch_p99_ms": 8.0,
                                   "query_p50_ms": 0.5,
                                   "query_p99_ms": 2.0}},
               "tail_noise": {"lsm_ingest_p99_over_p50":
                              {"repeats": [7.0, 8.0, 10.0], "spread": 3.0},
                              "lsm_query_p99_over_p50":
                              {"repeats": [4.0, 4.4], "spread": 0.4}}}
TAIL_QUERY = {"rows": [{"fused_speedup": 1.5, "fused_p50_us": 100.0,
                        "fused_p99_us": 400.0}],
              "scan_rows": [{"range_len": 64, "scan_speedup": 2.2,
                             "scan_p50_us": 200.0, "scan_p99_us": 900.0}]}


def test_extract_tail_ratios_and_noise():
    tails = extract_tail_ratios(TAIL_INGEST, TAIL_QUERY)
    assert tails == {"lsm_ingest_p99_over_p50": 8.0,
                     "lsm_query_p99_over_p50": 4.0,
                     "fused_read_p99_over_p50": 4.0,
                     "scan_p99_over_p50": 4.5}
    assert extract_tail_noise(TAIL_INGEST) == {
        "lsm_ingest_p99_over_p50": 3.0, "lsm_query_p99_over_p50": 0.4}
    # old artifacts without tail fields -> no table, no noise floor
    assert extract_tail_ratios(BASE_INGEST, BASE_QUERY) == {}
    assert extract_tail_noise(BASE_INGEST) == {}
    assert tail_markdown({}, {}) == ""
    assert tail_gate_markdown([], 0.5) == ""


def test_compare_tails_budget_semantics():
    base = extract_tail_ratios(TAIL_INGEST, TAIL_QUERY)
    noise = extract_tail_noise(TAIL_INGEST)
    # identical run -> all green
    rows, ok = compare_tails(base, noise, dict(base), threshold=0.5)
    assert ok and all(r["status"] == "ok" for r in rows)
    # within the relative threshold -> green (1.4x < 1.5x budget)
    mild = {k: v * 1.4 for k, v in base.items()}
    _, ok = compare_tails(base, noise, mild, threshold=0.5)
    assert ok
    # the noise floor dominates when it is wider than the threshold:
    # lsm_ingest budget = max(8*1.5, 8+3) = 12 -> 11.5 green, 12.5 red
    _, ok = compare_tails(base, noise,
                          dict(base, lsm_ingest_p99_over_p50=11.5), 0.5)
    assert ok
    rows, ok = compare_tails(base, noise,
                             dict(base, lsm_ingest_p99_over_p50=12.5), 0.5)
    assert not ok
    flags = {r["ratio"]: r["status"] for r in rows}
    assert flags["lsm_ingest_p99_over_p50"] == "REGRESSED"
    assert flags["scan_p99_over_p50"] == "ok"
    # one-sided: a shrinking tail is always green
    _, ok = compare_tails(base, noise, {k: v * 0.1 for k, v in base.items()},
                          threshold=0.5)
    assert ok
    # fail-closed: a baselined family missing from the fresh run is red
    rows, ok = compare_tails(base, noise,
                             {k: v for k, v in base.items()
                              if k != "scan_p99_over_p50"}, 0.5)
    assert not ok
    assert {r["ratio"]: r["status"] for r in rows}["scan_p99_over_p50"] \
        == "MISSING"
    # a family only the fresh run reports stays advisory
    rows, ok = compare_tails(base, noise, dict(base, brand_new_tail=9.0),
                             threshold=0.5)
    assert ok
    assert {r["ratio"]: r["status"] for r in rows}["brand_new_tail"] \
        == "untracked"


def test_tail_gate_main_red_green_and_bootstrap(tmp_path, monkeypatch):
    """End-to-end through main(): a tail blowup with tracked ratios
    unchanged must red the gate once a tail baseline is committed, stay
    advisory without one, and --write-tail-baseline must emit a baseline
    that gates a subsequent identical run green."""
    bi, bq = tmp_path / "bi.json", tmp_path / "bq.json"
    bi.write_text(json.dumps(TAIL_INGEST))
    bq.write_text(json.dumps(TAIL_QUERY))
    worse = json.loads(json.dumps(TAIL_QUERY))
    worse["rows"][0]["fused_p99_us"] *= 100   # tail blowup, speedups same
    wq = tmp_path / "wq.json"
    wq.write_text(json.dumps(worse))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    # bootstrap: no tail baseline -> advisory table, exit 0 even on blowup
    no_tails = str(tmp_path / "no_tails.json")
    argv = ["--baseline-ingest", str(bi), "--baseline-query", str(bq),
            "--new-ingest", str(bi)]
    assert main(argv + ["--new-query", str(wq),
                        "--tail-baseline", no_tails]) == 0
    assert "Tail latency (advisory)" in summary.read_text()
    # write a tail baseline from the clean run, then gate against it
    tails_path = str(tmp_path / "tails.json")
    assert main(argv + ["--new-query", str(bq),
                        "--write-tail-baseline", tails_path]) == 0
    committed = json.loads((tmp_path / "tails.json").read_text())
    assert committed["tails"]["fused_read_p99_over_p50"] == 4.0
    assert committed["noise_floor"]["lsm_ingest_p99_over_p50"] == 3.0
    # identical fresh run -> green, gated table in the summary
    summary.write_text("")
    assert main(argv + ["--new-query", str(bq),
                        "--tail-baseline", tails_path]) == 0
    assert "Tail latency gate" in summary.read_text()
    # 100x fused-read tail blowup -> red, even though every tracked
    # speedup ratio is untouched
    assert main(argv + ["--new-query", str(wq),
                        "--tail-baseline", tails_path]) == 1
    assert "REGRESSED" in summary.read_text()


def test_markdown_table_shape():
    base = extract_ratios(BASE_INGEST, BASE_QUERY)
    rows, _ = compare(base, base)
    md = markdown(rows, 0.2)
    assert md.count("|") >= 5 * (len(rows) + 2)
    for name in base:
        assert name in md
