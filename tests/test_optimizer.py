"""Optimizer: AdamW trajectory sanity, quantized-state fidelity, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   lr_at, opt_state_specs)


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] - 1.0) ** 2)


def run_steps(cfg, steps=300):
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    for _ in range(steps):
        grads = jax.grad(quad_loss)(params)
        params, state = adamw_update(grads, state, params, cfg)
    return params


def test_adamw_converges():
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=10, total_steps=300,
                      weight_decay=0.0)
    p = run_steps(cfg)
    np.testing.assert_allclose(np.asarray(p["w"]), 3.0, atol=0.05)
    np.testing.assert_allclose(np.asarray(p["b"]), 1.0, atol=0.05)


def test_quantized_states_track_fp32():
    cfg32 = AdamWConfig(peak_lr=0.05, warmup_steps=10, total_steps=300,
                        weight_decay=0.0)
    cfg8 = AdamWConfig(peak_lr=0.05, warmup_steps=10, total_steps=300,
                       weight_decay=0.0, quantized_state=True)
    p32, p8 = run_steps(cfg32), run_steps(cfg8)
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(p32["w"]),
                               atol=0.1)


def test_quantized_state_memory_layout():
    from repro.models.spec import PSpec
    specs = {"w": PSpec((128, 256), ("embed", "ff"), jnp.bfloat16)}
    os8 = opt_state_specs(specs, AdamWConfig(quantized_state=True))
    assert os8["m"]["w"]["q"].dtype == jnp.int8
    assert os8["m"]["w"]["q"].shape == (128, 256)
    assert os8["m"]["w"]["q"].axes == ("embed", "ff")  # sharding preserved
    assert os8["m"]["w"]["s"].shape == (128, 1)


def test_grad_clip_applies():
    cfg = AdamWConfig(peak_lr=0.1, grad_clip=1e-6, warmup_steps=0,
                      total_steps=10, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 1e6)}
    new_p, _ = adamw_update(grads, state, params, cfg)
    # clipped grads -> tiny update magnitude despite huge raw grads
    assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 0.2


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=100, total_steps=1000)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(1000))) == pytest.approx(0.1, abs=0.01)
    assert float(lr_at(cfg, jnp.asarray(550))) < 1.0
