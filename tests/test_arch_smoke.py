"""Per-architecture smoke tests on REDUCED configs (assignment requirement):
one forward/train step on CPU asserting output shapes + no NaNs, plus a
prefill<->decode consistency check that exercises the KV-cache / SSM-state
serving path against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import build, init_params
from repro.models.spec import sds_tree

SEQ = 32
BATCH = 2
IDENTITY_SH = lambda x, *a: x  # noqa: E731


def make_batch(model, rng, seq=SEQ, batch=BATCH, kind="train"):
    cfg = model.cfg
    specs = (model.train_input_specs if kind == "train"
             else model.prefill_input_specs)(batch, seq)
    out = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            out[k] = jnp.asarray(rng.integers(1, cfg.vocab, s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch):
    cfg = get_reduced(arch)
    model = build(cfg)
    params = init_params(model.param_specs, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = make_batch(model, rng)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: model.train_loss(p, b, IDENTITY_SH, "dots")))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss={loss}"
    leaf_ok = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree.leaves(leaf_ok)), f"{arch} has non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch):
    """logits(prefill t[0:n]) then decode(t[n]) must equal prefill(t[0:n+1]).

    This is the strongest cheap correctness check of the serving path: for
    SSM archs it validates the chunked-SSD <-> stepwise recurrence duality."""
    cfg = get_reduced(arch)
    model = build(cfg)
    params = init_params(model.param_specs, jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = make_batch(model, rng, kind="prefill")
    tokens = batch["tokens"]
    n = tokens.shape[1]

    # ground truth: prefill over the full sequence -> last-token logits
    logits_full = jax.jit(lambda p, b: model.prefill(p, b, IDENTITY_SH))(
        params, batch)[0]

    # serve path: prefill on the prefix, then one decode step
    prefix = dict(batch)
    prefix["tokens"] = tokens[:, :-1]
    out = jax.jit(lambda p, b: _prefix_prefill(model, p, b))(params, prefix)
    logits_dec = _decode_last(model, params, out, tokens, batch)

    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32), np.asarray(logits_full, np.float32),
        rtol=5e-2, atol=5e-2)


def _prefix_prefill(model, params, prefix_batch):
    cfg = model.cfg
    if cfg.family == "ssm":
        return model.prefill(params, prefix_batch, IDENTITY_SH)
    # others need max_len = full length for the later decode write
    full_len = prefix_batch["tokens"].shape[1] + 1
    from repro.models import encdec, hybrid, transformer, vlm
    if cfg.family in ("dense", "moe"):
        return transformer.prefill(cfg, params, prefix_batch["tokens"],
                                   IDENTITY_SH, max_len=full_len)
    if cfg.family == "vlm":
        return vlm.prefill(cfg, params, prefix_batch["img_embeds"],
                           prefix_batch["tokens"], IDENTITY_SH,
                           max_len=full_len + cfg.n_img_tokens)
    if cfg.family == "encdec":
        return encdec.prefill(cfg, params, prefix_batch["frames"],
                              prefix_batch["tokens"], IDENTITY_SH,
                              max_len=full_len)
    if cfg.family == "hybrid":
        return hybrid.prefill(cfg, params, prefix_batch["tokens"],
                              IDENTITY_SH, max_len=full_len)
    raise ValueError(cfg.family)


def _decode_last(model, params, prefill_out, tokens, batch):
    cfg = model.cfg
    last = tokens[:, -1:]
    n = tokens.shape[1]
    if cfg.family == "ssm":
        _, states = prefill_out
        logits, _ = jax.jit(lambda p, b: model.decode(p, b, IDENTITY_SH))(
            params, {"token": last, "cache": states})
        return logits
    if cfg.family == "encdec":
        _, cache, cross = prefill_out
        logits, _ = jax.jit(lambda p, b: model.decode(p, b, IDENTITY_SH))(
            params, {"token": last, "cache": cache, "cross": cross,
                     "pos": jnp.asarray(n - 1, jnp.int32)})
        return logits
    _, cache = prefill_out
    pos = n - 1 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    logits, _ = jax.jit(lambda p, b: model.decode(p, b, IDENTITY_SH))(
        params, {"token": last, "cache": cache,
                 "pos": jnp.asarray(pos, jnp.int32)})
    return logits


def test_mamba2_ssd_matches_naive_recurrence():
    """Chunked SSD vs step-by-step recurrence oracle (tiny dims)."""
    from repro.models import mamba2
    cfg = get_reduced("mamba2-2.7b")
    specs = mamba2.mamba_specs(cfg)
    from repro.models import init_params as ip
    p = jax.tree.map(lambda x: x, ip(specs, jax.random.key(3)))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, SEQ, cfg.d_model)) * 0.3, cfg.dtype)
    y_chunk, (state, conv) = mamba2.apply_mamba(cfg, p, x, IDENTITY_SH,
                                                return_state=True)
    # naive: feed tokens one at a time through mamba_decode
    di, nst, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    ss = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_headdim, nst), jnp.float32)
    cs = jnp.zeros((2, k - 1, di + 2 * nst), cfg.dtype)
    ys = []
    for t in range(SEQ):
        yt, ss, cs = mamba2.mamba_decode(cfg, p, x[:, t, :], ss, cs,
                                         IDENTITY_SH)
        ys.append(yt)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_naive, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(state), np.asarray(ss),
                               rtol=5e-2, atol=5e-2)
