"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import I32_MAX
from repro.kernels.merge_rank import merge_sorted, merge_sorted_ref
from repro.kernels.segment_reduce import segment_sum, segment_sum_ref
from repro.kernels.sorted_search import sorted_search, sorted_search_ref
from repro.kernels.spmv import ell_from_coo, spmv_ell, spmv_ell_ref

rng = np.random.default_rng(7)


# ---------------------------------------------------------------- sorted_search
@pytest.mark.parametrize("n_tab", [1, 5, 300, 2048, 5000])
@pytest.mark.parametrize("n_q", [1, 7, 257])
@pytest.mark.parametrize("side", ["left", "right"])
def test_sorted_search_matches_ref(n_tab, n_q, side):
    tab = np.sort(rng.integers(0, 500, n_tab)).astype(np.int32)
    q = rng.integers(-5, 510, n_q).astype(np.int32)
    got = sorted_search(jnp.asarray(tab), jnp.asarray(q), side=side,
                        block_q=64, block_t=256)
    want = sorted_search_ref(jnp.asarray(tab), n_tab, jnp.asarray(q), side=side)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sorted_search_padded_table():
    """Valid-prefix semantics: pads (I32_MAX) beyond n never count."""
    tab = np.full(100, I32_MAX, dtype=np.int32)
    tab[:10] = np.arange(10) * 3
    q = np.asarray([0, 1, 29, 100], dtype=np.int32)
    got = sorted_search(jnp.asarray(tab), jnp.asarray(q), block_q=64, block_t=64)
    want = sorted_search_ref(jnp.asarray(tab), 10, jnp.asarray(q))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------------ merge_rank
def _rand_run(n, n_valid, seed):
    r = np.random.default_rng(seed)
    rows = np.sort(r.integers(0, 40, n_valid)).astype(np.int32)
    cols = r.integers(0, 40, n_valid).astype(np.int32)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = r.normal(size=n_valid).astype(np.float32)
    pr = np.full(n, I32_MAX, np.int32); pr[:n_valid] = rows
    pc = np.full(n, I32_MAX, np.int32); pc[:n_valid] = cols
    pv = np.zeros(n, np.float32); pv[:n_valid] = vals
    return pr, pc, pv


@pytest.mark.parametrize("na,va,nb,vb", [
    (8, 8, 8, 8), (64, 50, 32, 17), (300, 123, 300, 300), (512, 0, 64, 33),
])
def test_merge_matches_ref(na, va, nb, vb):
    ar, ac, av = _rand_run(na, va, 1)
    br, bc, bv = _rand_run(nb, vb, 2)
    gr, gc, gv = merge_sorted(*(jnp.asarray(x) for x in (ar, ac, av, br, bc, bv)),
                              block_q=64, block_t=64)
    wr, wc, wv = merge_sorted_ref(*(jnp.asarray(x) for x in (ar, ac, av, br, bc, bv)))
    n = va + vb  # valid prefix of merged output
    np.testing.assert_array_equal(np.asarray(gr)[:n], np.asarray(wr)[:n])
    np.testing.assert_array_equal(np.asarray(gc)[:n], np.asarray(wc)[:n])
    np.testing.assert_allclose(np.asarray(gv)[:n], np.asarray(wv)[:n])
    assert np.all(np.asarray(gr)[n:] == I32_MAX)


def test_merge_tie_order_b_after_a():
    """Equal keys: A-side (old) entries precede B-side (new) -> last-wins dedup."""
    a = (jnp.asarray([3], jnp.int32), jnp.asarray([4], jnp.int32),
         jnp.asarray([1.0], jnp.float32))
    b = (jnp.asarray([3], jnp.int32), jnp.asarray([4], jnp.int32),
         jnp.asarray([2.0], jnp.float32))
    _, _, v = merge_sorted(*a, *b, block_q=64, block_t=64)
    np.testing.assert_allclose(np.asarray(v)[:2], [1.0, 2.0])


# -------------------------------------------------------------- segment_reduce
@pytest.mark.parametrize("n", [1, 100, 1025, 4096])
@pytest.mark.parametrize("n_seg", [1, 17, 512, 1000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_segment_sum_matches_ref(n, n_seg, dtype):
    ids = rng.integers(-1, n_seg, n).astype(np.int32)  # includes dropped -1s
    vals = rng.integers(0, 7, n).astype(np.asarray(jnp.zeros(0, dtype)).dtype)
    got = segment_sum(jnp.asarray(ids), jnp.asarray(vals), n_segments=n_seg,
                      block_n=128, block_s=64)
    want = segment_sum_ref(jnp.asarray(ids), jnp.asarray(vals), n_seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ------------------------------------------------------------------------ spmv
@pytest.mark.parametrize("n_rows,n_cols,nnz", [
    (1, 1, 1), (10, 10, 30), (100, 257, 900), (300, 2100, 5000),
])
def test_spmv_matches_ref(n_rows, n_cols, nnz):
    r = np.sort(rng.integers(0, n_rows, nnz))
    c = rng.integers(0, n_cols, nnz)
    v = rng.normal(size=nnz).astype(np.float32)
    cols, vals = ell_from_coo(r, c, v, n_rows)
    x = rng.normal(size=n_cols).astype(np.float32)
    got = spmv_ell(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x),
                   block_r=64, block_c=128)
    want = spmv_ell_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_spmv_duplicate_cols_accumulate():
    cols = jnp.asarray([[0, 0, -1]], jnp.int32)
    vals = jnp.asarray([[2.0, 3.0, 99.0]], jnp.float32)
    x = jnp.asarray([10.0], jnp.float32)
    got = spmv_ell(cols, vals, x, block_r=64, block_c=128)
    np.testing.assert_allclose(np.asarray(got), [50.0])
