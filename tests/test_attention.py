"""Blocked (flash-style) attention must match naive SDPA exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _blocked_sdpa, _sdpa


@pytest.mark.parametrize("sq,sk,h,kvh,qb,kb", [
    (256, 256, 8, 8, 64, 64),
    (512, 512, 8, 2, 128, 256),   # GQA
    (128, 512, 4, 4, 64, 128),    # decode-ish: short q, long cache
])
@pytest.mark.parametrize("causal", [True, False])
def test_blocked_matches_naive(sq, sk, h, kvh, qb, kb, causal):
    rng = np.random.default_rng(0)
    b, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), jnp.float32)
    off = sk - sq if causal else None
    want = _sdpa(q, k, v, causal=causal, q_offset=off)
    got = _blocked_sdpa(q, k, v, causal=causal, q_offset=off, qb=qb, kb=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_blocked_grads_finite():
    rng = np.random.default_rng(1)
    b, s, h, hd = 1, 256, 4, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)

    def f(q):
        return jnp.sum(_blocked_sdpa(q, q[:, :, :2], q[:, :, 2:],
                                     causal=True, qb=64, kb=64) ** 2)

    g = jax.grad(f)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
