"""Data pipeline + serving engine + graph500 determinism tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import TokenStore, synthetic_corpus
from repro.data.graph500 import graph500_triples, kronecker_edges
from repro.models import build, init_params
from repro.serve import Engine, Request


def test_graph500_shapes_and_determinism():
    u1, v1 = kronecker_edges(8, 16, seed=3)
    u2, v2 = kronecker_edges(8, 16, seed=3)
    assert len(u1) == 16 * 256
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_array_equal(v1, v2)
    u3, _ = kronecker_edges(8, 16, seed=4)
    assert not np.array_equal(u1, u3)
    assert u1.max() < 256
    # power law: a few hubs own a large share of out-edges
    counts = np.bincount(u1)
    top = np.sort(counts)[-8:].sum()
    assert top > 0.15 * len(u1)


def test_vertex_strings_sort_like_ints():
    from repro.data.graph500 import vertex_strings
    ids = np.asarray([5, 100, 3, 50])
    s = vertex_strings(ids)
    assert list(np.argsort(s)) == list(np.argsort(ids))


def test_token_store_roundtrip():
    store = TokenStore(num_shards=2, capacity_per_shard=1 << 14, max_docs=64)
    docs = synthetic_corpus(8, 100, vocab=1000, seed=1)
    store.ingest(docs)
    for i in (0, 3, 7):
        np.testing.assert_array_equal(store.get_doc(i), docs[i])
    rng = np.random.default_rng(0)
    batch = store.sample_batch(4, 32, rng)
    assert batch.shape == (4, 32)
    assert batch.max() < 1000


def test_engine_serves_batched_requests():
    cfg = get_reduced("smollm-135m")
    model = build(cfg)
    params = init_params(model.param_specs, jax.random.key(0))
    engine = Engine(model, params, batch_slots=3, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, 7).astype(np.int32),
                    max_new=5) for _ in range(5)]
    stats = engine.run(reqs)
    assert all(r.out is not None and len(r.out) == 5 for r in reqs)
    assert stats["tokens_out"] == 25
    # greedy decode must be deterministic across engine instances
    reqs2 = [Request(prompt=reqs[0].prompt.copy(), max_new=5)]
    Engine(model, params, batch_slots=1, max_len=64).run(reqs2)
    np.testing.assert_array_equal(reqs2[0].out, reqs[0].out)
