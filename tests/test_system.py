"""End-to-end behaviour of the paper's system: Graph500 data flows through
ingest -> schema upkeep -> queries -> analytics -> LM training, on one code
path (the D4M store is the framework's data plane, DESIGN §4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Assoc
from repro.data import TokenStore, synthetic_corpus
from repro.data.graph500 import graph500_triples
from repro.db import EdgeSchema, dbsetup
import pytest


@pytest.mark.slow
def test_paper_pipeline_end_to_end():
    # 1. ingest a power-law graph with the D4M 2.0 schema
    server = dbsetup("e2e", num_shards=4, capacity_per_shard=1 << 16,
                     batch_cap=1 << 14, id_capacity=1 << 18)
    g = EdgeSchema(server, "g")
    rows, cols, vals = graph500_triples(scale=8, edges_per_vertex=8, seed=42)
    g.put_triple(rows, cols, vals)
    oracle = Assoc(rows, cols, vals, func="last")
    assert g.nnz() == oracle.nnz()

    # 2. degree table agrees with the data
    hub_deg = int(np.bincount(server.keydict.lookup(rows)).max())
    hubs = g.deg.vertices_with_degree(hub_deg, "out", tol=1.001)
    assert len(hubs) >= 1

    # 3. row + transpose-routed column queries match the Assoc oracle
    probe = str(hubs[0]) + ","
    assert g[probe, :].same_as(oracle[probe, :])
    assert g[:, probe].same_as(oracle[:, probe])

    # 4. two-hop BFS via associative-array matmul stays consistent
    sub = g[probe, :]
    hop2 = sub * g[("".join(s + "," for s in sub.col)), :]
    assert hop2.nnz() > 0
    assert set(hop2.row) == {str(hubs[0])}


@pytest.mark.slow
def test_store_backed_training_reduces_loss():
    from repro.configs import get_reduced
    from repro.models import build, init_params
    from repro.train import AdamWConfig, adamw_init
    from repro.train.train_step import make_train_step

    store = TokenStore(num_shards=2, capacity_per_shard=1 << 14, max_docs=64)
    store.ingest(synthetic_corpus(16, 256, vocab=500, seed=0))

    model = build(get_reduced("smollm-135m"))
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=3, total_steps=30)
    step = jax.jit(make_train_step(model, opt_cfg))
    params = init_params(model.param_specs, jax.random.key(0))
    opt = adamw_init(params, opt_cfg)
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(30):
        batch = {"tokens": jnp.asarray(store.sample_batch(4, 64, rng))}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
