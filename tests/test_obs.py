"""Observability layer tests (ISSUE 6): histogram quantile accuracy vs a
numpy oracle, labeled-series aggregation, cross-process snapshot merging,
span nesting/ring eviction/slow-op capture, Chrome-trace export shape,
engine counter-schema parity, DBserver.metrics(), and the disabled-mode
overhead budget (instrumentation must cost <2% of a query when off)."""
import json
import math
import time
from time import perf_counter

import numpy as np
import pytest

from repro.db import dbsetup
from repro.db.kvstore import ShardedTable
from repro.obs import (Counter, Gauge, Histogram, Registry, Tracer,
                       default_registry, default_tracer, merge_snapshots,
                       set_enabled)

# histogram buckets grow by 2**(1/8): any sample's representative is
# within ~4.4% of the true value; 12% headroom covers rank-vs-bucket
# interaction at sparse tails
QUANT_RTOL = 0.12


# ------------------------------------------------------------- histograms
def _fill(h, xs):
    for x in xs:
        h.observe(float(x))


@pytest.mark.parametrize("dist", ["powerlaw", "constant", "bimodal"])
def test_histogram_quantiles_vs_numpy_oracle(dist):
    rng = np.random.default_rng(42)
    n = 20_000
    if dist == "powerlaw":          # latency-shaped heavy tail
        xs = 1e-4 * (1.0 + rng.pareto(1.5, n))
    elif dist == "constant":
        xs = np.full(n, 3.7e-3)
    else:                           # fast path + slow path mixture
        xs = np.where(rng.random(n) < 0.9,
                      np.abs(rng.normal(2e-4, 2e-5, n)),
                      np.abs(rng.normal(2e-2, 2e-3, n)))
    reg = Registry()
    h = reg.histogram("t_lat")
    _fill(h, xs)
    assert h.count == n
    assert h.min == pytest.approx(xs.min()) and h.max == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean(), rel=1e-6)
    for q in (0.50, 0.90, 0.99, 0.999):
        got = h.quantile(q)
        # nearest-rank oracle (matches the histogram's rank definition)
        want = float(np.quantile(xs, q, method="inverted_cdf"))
        if dist == "constant":
            assert got == pytest.approx(want, rel=1e-12), q
        else:
            assert got == pytest.approx(want, rel=QUANT_RTOL), (q, got, want)
    p = h.percentiles()
    assert set(p) == {"p50", "p90", "p99", "p999"}
    assert p["p50"] <= p["p90"] <= p["p99"] <= p["p999"]


def test_histogram_merge_equals_pooled():
    """Merging two histograms must equal one histogram fed all samples —
    exactly, bucket for bucket (same fixed layout; only float ``sum`` is
    order-dependent)."""
    rng = np.random.default_rng(7)
    a, b = rng.exponential(1e-3, 5000), rng.exponential(5e-3, 3000)
    reg = Registry()
    ha, hb, pooled = (reg.histogram("m", part=i) for i in range(3))
    _fill(ha, a)
    _fill(hb, b)
    _fill(pooled, np.concatenate([a, b]))
    merged = reg.histogram("m", part=9)
    merged.merge(ha)
    merged.merge(hb)
    assert merged._buckets == pooled._buckets
    assert merged.count == pooled.count == 8000
    assert merged.min == pooled.min and merged.max == pooled.max
    assert merged.sum == pytest.approx(pooled.sum, rel=1e-9)
    for q in (0.5, 0.99):
        assert merged.quantile(q) == pooled.quantile(q)
    # snapshot -> load_snapshot round-trip preserves buckets
    h2 = reg.histogram("m", part=10)
    h2.load_snapshot(pooled.snapshot())
    assert h2._buckets == pooled._buckets and h2.count == pooled.count


def test_empty_histogram_is_nan_and_snapshot_minimal():
    h = Registry().histogram("e")
    assert math.isnan(h.quantile(0.5)) and math.isnan(h.mean)
    assert h.snapshot() == {"count": 0, "sum": 0.0}


# --------------------------------------------------------------- registry
def test_registry_series_identity_labels_and_kind_guard():
    reg = Registry()
    c1 = reg.counter("hits", table="t", shard=0)
    c2 = reg.counter("hits", shard=0, table="t")   # label order irrelevant
    assert c1 is c2
    c1.inc()
    c1.inc(4)
    assert c2.value == 5
    with pytest.raises(TypeError):
        reg.histogram("hits", table="t", shard=0)  # kind mismatch
    g = reg.gauge("depth", table="t")
    g.set(3.5)
    assert g.value == 3.5


def test_label_aggregation_and_filtering():
    reg = Registry()
    for s in range(4):
        reg.counter("ops", table="a", shard=s).inc(s + 1)
    reg.counter("ops", table="b", shard=0).inc(100)
    assert reg.aggregate("ops", table="a") == 1 + 2 + 3 + 4
    assert reg.aggregate("ops") == 110
    assert reg.aggregate("ops", table="a", shard=2) == 3
    assert reg.aggregate("nosuch") is None
    assert len(reg.series("ops", table="a")) == 4
    # histogram aggregation merges across the filtered series
    for s, v in ((0, 1e-3), (1, 4e-3)):
        h = reg.histogram("lat", table="a", shard=s)
        for _ in range(10):
            h.observe(v)
    agg = reg.aggregate("lat", table="a")
    assert agg["count"] == 20
    assert agg["min"] == pytest.approx(1e-3) and agg["max"] == pytest.approx(4e-3)


def test_merge_snapshots_across_processes():
    """Per-process registry snapshots merge at the host: counters sum,
    histograms bucket-merge (the spmd per-process path)."""
    snaps = []
    for proc in range(3):
        reg = Registry()
        reg.counter("n_steps", op="ingest").inc(10 * (proc + 1))
        h = reg.histogram("step_s", op="ingest")
        for _ in range(50):
            h.observe(1e-3 * (proc + 1))
        snaps.append(reg.snapshot())
    merged = merge_snapshots(snaps)
    assert merged["n_steps{op=ingest}"] == 60
    hs = merged["step_s{op=ingest}"]
    assert hs["count"] == 150
    assert hs["min"] == pytest.approx(1e-3) and hs["max"] == pytest.approx(3e-3)
    from repro.db.spmd import merge_process_metrics
    assert merge_process_metrics(snaps) == merged


def test_registry_disabled_is_noop():
    reg = Registry(enabled=False)
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(5)
    g.set(2.0)
    h.observe(1e-3)
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    reg.enabled = True
    c.inc(5)
    assert c.value == 5


# ---------------------------------------------------------------- tracing
def test_span_nesting_and_ring_eviction():
    tr = Tracer(capacity=4, slow_threshold_s=10.0)
    with tr.span("outer", table="t"):
        with tr.span("inner"):
            pass
    spans = tr.spans()
    inner, outer = spans[-2], spans[-1]   # inner exits (records) first
    assert inner["name"] == "inner" and inner["depth"] == 1 \
        and inner["parent"] == "outer"
    assert outer["name"] == "outer" and outer["depth"] == 0 \
        and outer["parent"] is None
    assert outer["labels"] == {"table": "t"}
    assert outer["dur"] >= inner["dur"] >= 0.0
    for i in range(6):                    # ring evicts oldest beyond cap
        with tr.span(f"s{i}"):
            pass
    assert [r["name"] for r in tr.spans()] == ["s2", "s3", "s4", "s5"]
    assert tr.slow_ops() == []            # nothing crossed 10s


def test_slow_op_log_and_exports(tmp_path):
    tr = Tracer(slow_threshold_s=0.005)
    with tr.span("fast"):
        pass
    with tr.span("slow", table="t", shard=1):
        time.sleep(0.012)
    slow = tr.slow_ops()
    assert [r["name"] for r in slow] == ["slow"]
    assert slow[0]["dur"] >= 0.005
    jpath, cpath = tmp_path / "trace.json", tmp_path / "chrome.json"
    tr.export_json(str(jpath))
    tr.export_chrome(str(cpath))
    j = json.loads(jpath.read_text())
    assert [s["name"] for s in j["spans"]] == ["fast", "slow"]
    assert j["slow_threshold_s"] == 0.005
    chrome = json.loads(cpath.read_text())
    evs = chrome["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X" and ev["cat"] == "repro.db"
        assert ev["dur"] >= 0 and "depth" in ev["args"]
    slow_ev = [e for e in evs if e["name"] == "slow"][0]
    assert slow_ev["dur"] >= 5_000        # microseconds
    assert slow_ev["args"]["table"] == "t"
    tr.clear()
    assert tr.spans() == [] and tr.slow_ops() == []


def test_disabled_tracer_hands_back_shared_null_span():
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a"), tr.span("b", x=1)
    assert s1 is s2                       # one shared no-op object
    with s1:
        pass
    assert tr.spans() == []
    assert tr.current_trace_id() is None
    assert tr.flight_recordings() == []


# ------------------------------------ trace context + flight recorder
def test_trace_id_propagation_root_allocates_children_inherit():
    tr = Tracer(slow_threshold_s=10.0)
    with tr.span("op_a", table="t") as root:
        a_trace = root.trace
        assert tr.current_trace_id() == a_trace
        with tr.span("kv") as child:
            assert child.trace == a_trace       # inherited, not fresh
            with tr.span("wal") as grand:
                assert grand.trace == a_trace
    with tr.span("op_b") as root_b:
        b_trace = root_b.trace
    assert a_trace != b_trace                   # one id per root op
    assert tr.current_trace_id() is None        # nothing open
    by_trace = {}
    for rec in tr.spans():
        by_trace.setdefault(rec["trace"], set()).add(rec["name"])
    assert by_trace[a_trace] == {"op_a", "kv", "wal"}
    assert by_trace[b_trace] == {"op_b"}


def test_histogram_exemplars_capture_merge_and_roundtrip():
    from repro.obs import span as gspan

    reg = Registry()
    h = reg.histogram("lat")
    h.observe(1e-3)                       # no open span -> no exemplar
    assert h.exemplars() == {}
    with gspan("op"):
        from repro.obs import current_trace
        tid = current_trace()
        assert tid is not None
        h.observe(2e-3)
        h.observe(64e-3)                  # different bucket, same trace
    ex = h.exemplars()
    assert len(ex) == 2
    assert all(t == tid for _v, t in ex.values())
    assert sorted(v for v, _t in ex.values()) == [2e-3, 64e-3]
    # snapshot carries them; load_snapshot round-trips into a sibling
    snap = h.snapshot()
    assert {e["trace"] for e in snap["exemplars"].values()} == {tid}
    h2 = reg.histogram("lat2")
    h2.load_snapshot(snap)
    assert h2.exemplars() == ex
    # merge propagates exemplars (latest-wins per bucket)
    h3 = reg.histogram("lat3")
    h3.merge(h)
    assert h3.exemplars() == ex
    # disabled registry: observe is a no-op, no exemplar capture even
    # under an open span (the kill switch gates the whole hot path)
    off = Registry(enabled=False)
    hoff = off.histogram("lat")
    with gspan("op2"):
        hoff.observe(5e-3)
    assert hoff.count == 0 and hoff.exemplars() == {}


def test_flight_recorder_captures_slow_trees_and_evicts():
    tr = Tracer(slow_threshold_s=0.005, flight_capacity=2)
    with tr.span("fast_root"):            # under threshold: not recorded
        with tr.span("child"):
            pass
    assert tr.flight_recordings() == []
    with tr.span("slow_root", table="t") as root:
        slow_trace = root.trace
        with tr.span("child_a"):
            pass
        with tr.span("child_b"):
            time.sleep(0.008)
    recs = tr.flight_recordings()
    assert len(recs) == 1
    rec = recs[0]
    assert rec["trace"] == slow_trace
    assert rec["root"]["name"] == "slow_root"
    # full tree in completion order, every span sharing the root's trace
    assert [s["name"] for s in rec["spans"]] == \
        ["child_a", "child_b", "slow_root"]
    assert all(s["trace"] == slow_trace for s in rec["spans"])
    # the root's wall includes its children: a slow child alone pushes
    # the root over the threshold, so the tree is still captured
    with tr.span("root2"):
        with tr.span("slow_child"):
            time.sleep(0.008)
    assert [r["root"]["name"] for r in tr.flight_recordings()] == \
        ["slow_root", "root2"]
    # bounded ring: capacity 2 keeps only the newest two recordings
    for i in range(3):
        with tr.span(f"slow_{i}"):
            time.sleep(0.006)
    names = [r["root"]["name"] for r in tr.flight_recordings()]
    assert len(names) == 2 and names == ["slow_1", "slow_2"]
    tr.clear()
    assert tr.flight_recordings() == []


# ------------------------------------------- engine/server instrumentation
_CFG = dict(num_shards=2, capacity_per_shard=2048, batch_cap=256,
            id_capacity=1 << 10, memtable_cap=64, l0_slots=4)


def _tiny(name, engine):
    st = ShardedTable(name, engine=engine, **_CFG)
    rng = np.random.default_rng(5)
    r = rng.integers(0, 1 << 10, 200).astype(np.int32)
    for i in range(0, 200, 50):           # memtable cap is 64
        st.insert(r[i:i + 50], np.zeros(50, np.int32),
                  np.ones(50, np.float32))
    st.flush()
    return st, r


def test_engine_stats_schema_parity_single_vs_lsm():
    """The single-run engine must emit the same counter schema as the LSM
    engine — zeros where the op doesn't apply — so dashboards and
    DBserver.metrics() don't special-case the engine."""
    lsm, r = _tiny("par_lsm", "lsm")
    single, _ = _tiny("par_single", "single")
    ks, kl = lsm.engine_stats(), single.engine_stats()
    assert set(ks) == set(kl)
    for k in ("fused_dispatches", "scan_dispatches", "runs_probed",
              "major_compactions"):
        assert kl[k] == 0, k              # structurally n/a -> zero
    assert kl["flushes"] >= 1 and ks["flushes"] >= 1
    q = np.unique(r[:8])
    lsm.query_rows(q)
    single.query_rows(q)
    assert lsm.engine_stats()["fused_dispatches"] >= 1
    assert single.engine_stats()["fused_dispatches"] == 0


def test_ingest_and_query_series_land_in_registry():
    st, r = _tiny("obs_tab", "lsm")
    reg = default_registry()
    per_shard = sum(c.value for c in reg.series("db_ingest_entries",
                                                table="obs_tab"))
    assert per_shard == 200               # every ingested entry attributed
    st.query_rows(np.unique(r[:16]))
    st.scan_range(0, 64)
    hq = reg.series("db_op_latency_s", table="obs_tab", op="query")
    hs = reg.series("db_op_latency_s", table="obs_tab", op="scan")
    assert len(hq) == 1 and hq[0].count >= 1 and hq[0].min > 0
    assert len(hs) == 1 and hs[0].count >= 1
    assert sum(c.value for c in
               reg.series("db_point_queries", table="obs_tab")) >= 1


def test_dbserver_metrics_and_dump(tmp_path):
    DB = dbsetup("obsdb", dict(num_shards=2, capacity_per_shard=4096,
                               batch_cap=2048, id_capacity=1 << 16))
    T = DB["mtab"]
    T.put_triple(np.asarray(["a", "b", "c"], object),
                 np.asarray(["x", "x", "y"], object),
                 np.asarray([1.0, 2.0, 3.0]))
    assert T["a,", :].nnz() == 1
    m = DB.metrics()
    assert m["instance"] == "obsdb"
    tab = m["tables"]["mtab"]
    assert set(tab["latency_s"]) == {"ingest", "query", "scan", "flush",
                                     "major_compaction"}
    assert tab["latency_s"]["ingest"]["count"] >= 1
    assert tab["counters"]["fused_dispatches"] >= 0
    assert set(tab["shards"]) == {"0", "1"}
    shard_ing = sum(s["ingest_entries"] for s in tab["shards"].values())
    assert shard_ing >= 3                 # transpose table is separate
    agg = m["aggregate"]
    assert agg["latency_s"]["ingest"]["count"] >= \
        tab["latency_s"]["ingest"]["count"]
    assert agg["counters"]["flushes"] >= 0
    path = tmp_path / "metrics.json"
    snap = DB.dump_metrics(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["instance"] == "obsdb"
    assert on_disk["tables"].keys() == snap["tables"].keys()


# ------------------------------------------------------- disabled overhead
def test_disabled_mode_overhead_budget():
    """Acceptance bar: with the registry disabled, the instrumentation
    left in the hot path must cost <2% of a point query. Measured as
    (actual instrument touches for one query) x (measured per-op disabled
    cost), against the measured query wall time.

    The v2 surface rides inside the same gated sites: trace-id allocation
    lives in ``_Span.__enter__`` (a disabled tracer hands back the shared
    null span, so no id is ever allocated) and exemplar capture lives in
    ``Histogram.observe`` AFTER the ``enabled`` early-return — so the
    disabled per-op costs measured below are the true all-in costs of the
    PR-9 instrumentation, not a subset."""
    st, r = _tiny("ovh_tab", "lsm")
    st.insert(r[:32], np.zeros(32, np.int32), np.ones(32, np.float32))
    q = np.unique(r[:8])
    st.query_rows(q)                      # warm the jit cache
    reps = 15
    times = []
    for _ in range(reps):
        t0 = perf_counter()
        st.query_rows(q)
        times.append(perf_counter() - t0)
    query_wall = sorted(times)[reps // 2]

    # count the instrument touches ONE query actually performs
    reg, tr = default_registry(), default_tracer()
    c0 = {id(i): i.value for i in reg.series() if i.kind == "counter"}
    h0 = {id(i): i.count for i in reg.series() if i.kind == "histogram"}
    tr.clear()
    st.query_rows(q)
    n_incs = sum(1 for i in reg.series()
                 if i.kind == "counter" and i.value != c0.get(id(i), 0))
    n_obs = sum(1 for i in reg.series()
                if i.kind == "histogram" and i.count != h0.get(id(i), 0))
    n_spans = len(tr.spans())
    assert n_spans >= 2 and n_obs >= 1    # instrumentation is actually live

    # per-op cost with everything disabled — these paths now also carry
    # the trace-context + exemplar machinery behind the same switches
    priv = Registry(enabled=False)
    ptr = Tracer(enabled=False)
    c, h = priv.counter("x"), priv.histogram("y")
    with ptr.span("probe"):
        h.observe(1e-3)                   # even under an "open" span...
    assert h.exemplars() == {} and ptr.flight_recordings() == []
    N = 20_000

    def cost(fn):
        best = math.inf
        for _ in range(3):
            t0 = perf_counter()
            for _ in range(N):
                fn()
            best = min(best, (perf_counter() - t0) / N)
        return best

    inc_cost = cost(c.inc)
    obs_cost = cost(lambda: h.observe(1e-3))
    span_cost = cost(lambda: ptr.span("s"))
    budget = (n_incs * inc_cost + n_obs * obs_cost
              + (n_spans + 2) * span_cost)
    assert budget < 0.02 * query_wall, (
        f"disabled-mode budget {budget * 1e6:.2f}us exceeds 2% of "
        f"query wall {query_wall * 1e6:.1f}us "
        f"(incs={n_incs} obs={n_obs} spans={n_spans})")


def test_set_enabled_kill_switch_round_trip():
    reg = default_registry()
    c = reg.counter("kill_switch_probe")
    c.reset()
    try:
        set_enabled(False)
        c.inc(7)
        assert c.value == 0
    finally:
        set_enabled(True)
    c.inc(7)
    assert c.value == 7
