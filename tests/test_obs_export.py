"""Exporter + debug-bundle surface (ISSUE 9): Prometheus text exposition
against a golden rendering (exemplars included), snapshot -> Registry
round-trip, the JSONL emitter, the health report, debug-bundle archives
(raw writer and ``DBserver.debug_bundle``), and the registry-asserted
zero-retrace guarantee across the 64..4096 query batch sweep."""
import json
import time
import zipfile

import numpy as np
import pytest

from repro.db import dbsetup
from repro.db.kvstore import ShardedTable
from repro.obs import (JsonlEmitter, Registry, Tracer, default_registry,
                       health_report, prometheus_text, write_debug_bundle)
from repro.obs.export import registry_from_snapshot
from repro.obs.metrics import _GROWTH, _LO


# ------------------------------------------------------- prometheus text
def _golden_registry():
    """Deterministic registry: exemplars injected via load_snapshot so
    the rendered text is reproducible regardless of test order (live
    spans would consume process-global trace ids)."""
    reg = Registry()
    reg.counter("db_ingest_entries", table="t", shard=0).inc(5)
    reg.gauge("lsm_read_amplification", table="t").set(1.5)
    h = reg.histogram("db_op_latency_s", table="t", op="query")
    h.load_snapshot({"count": 3, "sum": 0.007, "min": 0.001, "max": 0.004,
                     "buckets": {"100": 2, "200": 1},
                     "exemplars": {"100": {"value": 0.001,
                                           "trace": "t000abc"}}})
    return reg


def test_prometheus_text_golden():
    le100 = repr(_LO * _GROWTH ** 100)
    le200 = repr(_LO * _GROWTH ** 200)
    want = [
        "# TYPE db_ingest_entries counter",
        'db_ingest_entries_total{shard="0",table="t"} 5',
        "# TYPE db_op_latency_s histogram",
        f'db_op_latency_s_bucket{{le="{le100}",op="query",table="t"}} 2'
        ' # {trace_id="t000abc"} 0.001',
        f'db_op_latency_s_bucket{{le="{le200}",op="query",table="t"}} 3',
        'db_op_latency_s_bucket{le="+Inf",op="query",table="t"} 3',
        'db_op_latency_s_sum{op="query",table="t"} 0.007',
        'db_op_latency_s_count{op="query",table="t"} 3',
        "# TYPE lsm_read_amplification gauge",
        'lsm_read_amplification{table="t"} 1.5',
    ]
    assert prometheus_text(_golden_registry()).splitlines() == want


def test_prometheus_text_live_exemplar_links_to_open_span():
    from repro.obs import current_trace, span
    reg = Registry()
    h = reg.histogram("lat", op="q")
    with span("golden_op"):
        tid = current_trace()
        h.observe(2e-3)
    text = prometheus_text(reg)
    assert f'# {{trace_id="{tid}"}} 0.002' in text


def test_registry_from_snapshot_round_trip():
    reg = _golden_registry()
    reg.gauge("occupancy", shard=1).set(0.25)
    snap = reg.snapshot()
    rebuilt = registry_from_snapshot(snap)
    assert rebuilt.snapshot() == snap
    # kinds survive: counters stay counters, float gauges stay gauges
    kinds = {i.name: i.kind for i in rebuilt.series()}
    assert kinds["db_ingest_entries"] == "counter"
    assert kinds["occupancy"] == "gauge"
    assert kinds["db_op_latency_s"] == "histogram"
    # exemplars survive the rebuild (Prometheus view still carries them)
    assert 'trace_id="t000abc"' in prometheus_text(rebuilt)


# ---------------------------------------------------------- jsonl emitter
def test_jsonl_emitter_on_demand_and_context_manager(tmp_path):
    reg = Registry()
    c = reg.counter("ticks")
    path = tmp_path / "metrics.jsonl"
    em = JsonlEmitter(str(path), reg=reg, interval_s=3600.0)
    c.inc()
    em.emit_once()
    c.inc()
    em.emit_once()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [l["metrics"]["ticks"] for l in lines] == [1, 2]
    assert lines[0]["ts"] <= lines[1]["ts"]
    # context manager: background thread started, final emit on exit even
    # if the interval never elapsed
    with JsonlEmitter(str(path), reg=reg, interval_s=3600.0):
        c.inc(10)
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines[-1]["metrics"]["ticks"] == 12


# ---------------------------------------------------------- health report
def test_health_report_sections_and_formats():
    reg = Registry()
    reg.counter("wal_appends", log="t").inc(4)
    reg.gauge("lsm_read_amplification", table="t").set(2.5)
    h = reg.histogram("db_op_latency_s", table="t", op="query")
    h.observe(1e-3)
    md = health_report(reg.snapshot(), fmt="md")
    assert "### Health gauges" in md and "### Counters" in md \
        and "### Latency histograms" in md
    assert "lsm_read_amplification{table=t}" in md
    assert "| wal_appends | 4 |" in md
    assert "db_op_latency_s{op=query,table=t}" in md
    term = health_report(reg.snapshot(), fmt="term")
    assert "== Health gauges ==" in term and "|" not in term
    # empty snapshot still renders every section head
    empty = health_report({}, fmt="md")
    assert "(none)" in empty


# ----------------------------------------------------------- debug bundle
def test_write_debug_bundle_round_trip(tmp_path):
    reg = Registry()
    reg.counter("ops").inc(3)
    tr = Tracer(slow_threshold_s=0.002)
    with tr.span("slow_op", table="t"):
        time.sleep(0.005)
    path = str(tmp_path / "bundle.zip")
    assert write_debug_bundle(path, reg=reg, tracer=tr,
                              extra={"geometry": {"shards": 2}}) == path
    with zipfile.ZipFile(path) as zf:
        names = set(zf.namelist())
        assert names == {"metrics.json", "prometheus.txt",
                         "slow_traces.json", "geometry.json"}
        metrics = json.loads(zf.read("metrics.json"))
        assert metrics["ops"] == 3
        assert "# TYPE ops counter" in zf.read("prometheus.txt").decode()
        slow = json.loads(zf.read("slow_traces.json"))
        assert slow["slow_threshold_s"] == 0.002
        assert [r["root"]["name"]
                for r in slow["flight_recordings"]] == ["slow_op"]
        assert json.loads(zf.read("geometry.json")) == {"shards": 2}


def test_dbserver_debug_bundle_archive(tmp_path):
    DB = dbsetup("bundledb", dict(num_shards=2, capacity_per_shard=4096,
                                  batch_cap=2048, id_capacity=1 << 16))
    T = DB["btab"]
    T.put_triple(np.asarray(["a", "b", "c"], object),
                 np.asarray(["x", "x", "y"], object),
                 np.asarray([1.0, 2.0, 3.0]))
    assert T["a,", :].nnz() == 1
    path = str(tmp_path / "db_bundle.zip")
    assert DB.debug_bundle(path) == path
    with zipfile.ZipFile(path) as zf:
        names = set(zf.namelist())
        assert {"metrics.json", "prometheus.txt", "slow_traces.json",
                "store_config.json", "resident_geometry.json",
                "metrics_view.json"} <= names
        cfg = json.loads(zf.read("store_config.json"))
        assert cfg["num_shards"] == 2 and cfg["capacity_per_shard"] == 4096
        geo = json.loads(zf.read("resident_geometry.json"))
        assert "btab" in geo
        g = geo["btab"]
        assert g["num_shards"] == 2 and len(g["memtable_n"]) == 2
        assert g["engine"] in ("single", "lsm")
        if g["engine"] == "lsm":
            assert len(g["resident_runs"]) == 2
        view = json.loads(zf.read("metrics_view.json"))
        assert view["instance"] == "bundledb"
        assert "health" in view["tables"]["btab"]


def test_export_cli_renders_snapshot_and_rejects_view(tmp_path, capsys):
    """The CLI takes a RAW registry snapshot (Registry.dump /
    debug-bundle metrics.json); the aggregated DBserver.dump_metrics
    view must be rejected with a clear message, not a TypeError."""
    from repro.obs.export import main
    snap_path = tmp_path / "reg.json"
    snap_path.write_text(json.dumps(_golden_registry().snapshot()))
    prom_path = tmp_path / "prom.txt"
    assert main(["--metrics", str(snap_path), "--format", "term",
                 "--prometheus", str(prom_path)]) == 0
    assert "== Health gauges ==" in capsys.readouterr().out
    assert 'trace_id="t000abc"' in prom_path.read_text()
    view = tmp_path / "view.json"
    view.write_text(json.dumps({"instance": "db", "tables": {},
                                "aggregate": {}}))
    with pytest.raises(SystemExit):
        main(["--metrics", str(view)])
    assert "dump_metrics() view" in capsys.readouterr().err


# ------------------------------------------------- retrace acceptance bar
def test_no_unexpected_retraces_across_query_batch_sweep():
    """ISSUE 9 acceptance criterion, registry-asserted: after
    ``warm_reads`` compiles the fused tile, NO query batch size in
    64..4096 may trigger a fresh XLA trace — the ``lsm_retraces`` counter
    and the compiled-shapes gauge must both hold still across the sweep
    (PR 5's 'no batch size ever retraces' invariant, now a metric)."""
    st = ShardedTable("retrace_sweep", num_shards=2,
                      capacity_per_shard=1 << 14, batch_cap=1024,
                      id_capacity=1 << 16, memtable_cap=1024, engine="lsm")
    rng = np.random.default_rng(11)
    rows = rng.integers(0, 1 << 16, 6144).astype(np.int32)
    for i in range(0, len(rows), 1024):
        st.insert(rows[i:i + 1024], np.zeros(1024, np.int32),
                  np.ones(1024, np.float32))
    st.flush()
    st.insert(rows[:256], np.zeros(256, np.int32),
              np.ones(256, np.float32))    # memtable tail stays resident
    st.warm_reads()
    reg = default_registry()

    def retraces():
        return sum(c.value for c in reg.series("lsm_retraces",
                                               table="retrace_sweep"))

    def shapes():
        return sum(g.value for g in reg.series("lsm_compiled_shapes",
                                               op="query"))

    warm_retraces, warm_shapes = retraces(), shapes()
    assert warm_retraces >= 1              # warm_reads really compiled
    q_pool = rng.choice(rows, 4096).astype(np.int32)
    for size in (64, 256, 1024, 2048, 4096):
        hit_rows, _c, _v = st.query_rows(q_pool[:size])
        assert len(hit_rows) > 0
        assert retraces() == warm_retraces, \
            f"batch {size} triggered a fresh trace"
        assert shapes() == warm_shapes, \
            f"batch {size} grew the compile cache"


def test_no_retraces_after_tablet_split_and_move():
    """The 'no retraces' invariant must survive TOPOLOGY changes: after a
    tablet split + move + rebalance, ``warm_reads`` (which probes ids
    sampled from each shard's OWNED ranges, not a uniform linspace) re-
    warms both serving shapes, and no query batch in 64..4096 may trace
    again — splits change routing values, never compiled shapes."""
    st = ShardedTable("retrace_tablets", num_shards=2,
                      capacity_per_shard=1 << 14, batch_cap=1024,
                      id_capacity=1 << 16, memtable_cap=1024, engine="lsm",
                      dynamic_tablets=True)
    rng = np.random.default_rng(29)
    # Zipf-skewed rows: the hot range drives a real split decision
    rows = ((rng.zipf(1.2, 6144) * 7) % (1 << 16)).astype(np.int32)
    for i in range(0, len(rows), 1024):
        st.insert(rows[i:i + 1024], np.zeros(1024, np.int32),
                  np.ones(1024, np.float32))
    assert st.split_tablet() is not None
    tm = st.tablet_map
    moved = int(tm.tablet_ids[-1])
    st.move_tablet(moved, 1 - int(tm.owners[tm.index_of(moved)]))
    st.maybe_rebalance()
    st.flush()
    st.warm_reads()
    reg = default_registry()

    def retraces():
        return sum(c.value for c in reg.series("lsm_retraces",
                                               table="retrace_tablets"))

    def shapes():
        return sum(g.value for g in reg.series("lsm_compiled_shapes",
                                               op="query"))

    warm_retraces, warm_shapes = retraces(), shapes()
    assert warm_retraces >= 1
    q_pool = rng.choice(rows, 4096).astype(np.int32)
    for size in (64, 256, 1024, 2048, 4096):
        hit_rows, _c, _v = st.query_rows(q_pool[:size])
        assert len(hit_rows) > 0
        assert retraces() == warm_retraces, \
            f"batch {size} retraced after split/move"
        assert shapes() == warm_shapes, \
            f"batch {size} grew the compile cache after split/move"
    # a FURTHER split + re-warm must also hold the line (values-only
    # routing updates: the compiled shapes are already resident)
    if st.split_tablet() is not None:
        st.flush()
        st.warm_reads()
        post_retraces, post_shapes = retraces(), shapes()
        st.query_rows(q_pool[:1024])
        assert retraces() == post_retraces
        assert shapes() == post_shapes
