"""Simulation-driven tablet split/merge testing (ISSUE 10).

The scylla-scripts ``split-sstables.py`` exemplar validates split policy
by cheap simulation against an oracle instead of real scale; same idea
here: drive Zipfian and sequential-key ingest streams through live
split/move/merge decisions and check, after every topology change, that

  * the dynamic table stays DIFFERENTIALLY EQUAL to a never-split oracle
    (all four combiners — migration re-inserts combined values, which
    must be a no-op under each);
  * the balance invariant holds after convergence: max/mean per-shard
    load on a fresh workload window ≤ 2.0 (the acceptance bar);
  * reads keep working across splits: point queries, range scans (global
    (row, col) order preserved under a skewed map), and the tablet-map
    SPMD bucketing routes exactly like the host map.

``FUZZ_BUDGET`` (weekly deep lane) widens the streams and round counts.
"""
import os

import numpy as np
import pytest

from repro.db.kvstore import COMBINERS, ShardedTable, shard_of
from repro.db.tablets import TabletMap

FUZZ_BUDGET = int(os.environ.get("FUZZ_BUDGET", "0"))

S = 4
ID_CAP = 1 << 12
ZIPF_S = 1.2  # hottest key ~18% of traffic: splittable below the 2.0 bar


def _mk(name, combiner="last", dynamic=True, **kw):
    return ShardedTable(name, num_shards=S, capacity_per_shard=1 << 14,
                        batch_cap=1024, id_capacity=ID_CAP,
                        combiner=combiner, memtable_cap=256, engine="lsm",
                        dynamic_tablets=dynamic, **kw)


def _zipf_batch(rng, n):
    r = (rng.zipf(ZIPF_S, n) % ID_CAP).astype(np.int32)
    c = rng.integers(0, 64, n).astype(np.int32)
    v = rng.normal(size=n).astype(np.float32)
    return r, c, v


def _assert_same_triples(got, want):
    """(rows, cols, vals) equality up to (row, col) reordering; values
    compare with float tolerance (combiners like ``sum`` accumulate in a
    different order once a migration pre-combines a shard's entries)."""
    rg, cg, vg = got
    rw, cw, vw = want
    og, ow = np.lexsort((cg, rg)), np.lexsort((cw, rw))
    np.testing.assert_array_equal(np.asarray(rg)[og], np.asarray(rw)[ow])
    np.testing.assert_array_equal(np.asarray(cg)[og], np.asarray(cw)[ow])
    np.testing.assert_allclose(np.asarray(vg)[og], np.asarray(vw)[ow],
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------- map-level properties
def test_uniform_map_matches_static_hash():
    """The starting map IS shard_of: enabling dynamic_tablets changes
    nothing until the first split."""
    rng = np.random.default_rng(0)
    for s in (1, 2, 3, 4, 7, 16):
        for cap in (512, 1 << 16, 1000003):
            tm = TabletMap.uniform(s, cap)
            ids = rng.integers(0, cap, 4096)
            np.testing.assert_array_equal(tm.owner_of(ids),
                                          shard_of(ids, s, cap))


def test_split_move_merge_roundtrip():
    tm = TabletMap.uniform(4, 1 << 12)
    right = tm.split(0, 100)
    assert tm.range_of(0) == (0, 100) and tm.range_of(right) == (100, 1024)
    assert tm.n == 5 and right == 4
    assert tm.move(right, 3) == 0
    assert tm.owner_of(np.asarray([50, 500]))[0] == 0
    assert tm.owner_of(np.asarray([500]))[0] == 3
    # merge requires one owner; move back first
    with pytest.raises(ValueError):
        tm.merge(0)
    tm.move(right, 0)
    assert tm.merge(0) == right
    assert tm.n == 4 and tm.range_of(0) == (0, 1024)
    # ids are stable and never reused
    assert tm.split(0, 100) == 5
    # interior-only split keys
    with pytest.raises(ValueError):
        tm.split(0, 0)


def test_segments_cover_in_key_order():
    tm = TabletMap.uniform(2, 1000)
    tm.split(0, 100)
    tm.move(2, 1)  # [100, 500) now on shard 1: owners are 0,1,1 in key order
    segs = tm.segments(50, 900)
    assert segs == [(0, 50, 100), (1, 100, 900)]  # adjacent coalesced
    covered = [(a, b) for _s, a, b in segs]
    assert covered[0][0] == 50 and covered[-1][1] == 900
    assert all(covered[i][1] == covered[i + 1][0]
               for i in range(len(covered) - 1))
    assert tm.segments(5, 5) == []


def test_manifest_roundtrip_preserves_identity():
    tm = TabletMap.uniform(4, 1 << 20)
    tm.split(2, (1 << 19) + 123)
    tm.move(4, 0)
    back = TabletMap.from_manifest(tm.to_manifest())
    assert back.to_manifest() == tm.to_manifest()
    ids = np.random.default_rng(3).integers(0, 1 << 20, 2048)
    np.testing.assert_array_equal(back.owner_of(ids), tm.owner_of(ids))


# ------------------------------------------- differential oracle (4 ways)
@pytest.mark.parametrize("combiner", COMBINERS)
def test_differential_vs_never_split_oracle_zipf(combiner):
    """Zipfian stream + live rebalance rounds: the splitting table must
    read back EXACTLY like the never-split oracle after every round —
    splits are metadata, moves re-insert combined values (a no-op under
    every combiner), and routing never loses or duplicates a triple."""
    st = _mk(f"tz_{combiner}", combiner=combiner)
    oracle = _mk(f"tz_oracle_{combiner}", combiner=combiner, dynamic=False)
    rng = np.random.default_rng(11)
    rounds = 6 + min(FUZZ_BUDGET, 30)
    for rd in range(rounds):
        for _ in range(4):
            r, c, v = _zipf_batch(rng, 200)
            st.insert(r, c, v)
            oracle.insert(r, c, v)
        st.maybe_rebalance()
        _assert_same_triples(st.scan(), oracle.scan())
    assert st.tablet_map.n > S  # the skew actually drove splits
    # point queries and range scans agree too (and scans stay sorted)
    q = (rng.zipf(ZIPF_S, 512) % ID_CAP).astype(np.int32)
    _assert_same_triples(st.query_rows(q), oracle.query_rows(q))
    got = st.scan_range(3, ID_CAP - 5)
    assert got[0].tolist() == sorted(got[0].tolist())
    _assert_same_triples(got, oracle.scan_range(3, ID_CAP - 5))


def test_differential_sequential_stream_with_merges():
    """Sequential keys sweep the id space left to right (time-series
    ingest): the hot tablet keeps moving, cold ranges behind it merge
    back. Differential equality must hold through split + merge + move
    churn."""
    st = _mk("tseq")
    oracle = _mk("tseq_oracle", dynamic=False)
    rng = np.random.default_rng(5)
    n_total = 2048 + 512 * min(FUZZ_BUDGET, 20)
    keys = np.arange(n_total, dtype=np.int64) % ID_CAP
    for i in range(0, n_total, 256):
        r = keys[i:i + 256].astype(np.int32)
        c = rng.integers(0, 16, len(r)).astype(np.int32)
        v = rng.normal(size=len(r)).astype(np.float32)
        st.insert(r, c, v)
        oracle.insert(r, c, v)
        st.maybe_rebalance()
        # merge the coldest adjacent pair once tablets pile up
        tm = st.tablet_map
        if tm.n > 2 * S:
            i_cold = int(np.argmin(tm.loads[:-1] + tm.loads[1:]))
            assert st.merge_tablet(int(tm.tablet_ids[i_cold]))
    assert st._c_tablet_merges.value > 0
    _assert_same_triples(st.scan(), oracle.scan())


# ------------------------------------------------------ balance invariant
def test_balance_converges_under_zipf():
    """Acceptance bar: after the policy converges on a Zipfian stream,
    a FRESH workload window routes with max/mean per-shard load ≤ 2.0
    (the never-split baseline concentrates ~60% of this stream on one
    shard: max/mean ≈ 2.4)."""
    st = _mk("tbal")
    rng = np.random.default_rng(23)
    rounds = 10 + min(FUZZ_BUDGET, 40)
    for _ in range(rounds):
        for _ in range(4):
            st.insert(*_zipf_batch(rng, 256))
        st.maybe_rebalance()
    tm = st.tablet_map
    fresh = (rng.zipf(ZIPF_S, 8192) % ID_CAP).astype(np.int64)
    per_shard = np.bincount(tm.owner_of(fresh), minlength=S)
    ratio = per_shard.max() / per_shard.mean()
    static = np.bincount(shard_of(fresh, S, ID_CAP), minlength=S)
    static_ratio = static.max() / static.mean()
    assert ratio <= 2.0, (ratio, per_shard.tolist(),
                          tm.to_manifest())
    assert ratio < static_ratio  # strictly better than never splitting
    # the balance gauge agrees with the recorded-load view
    from repro.obs import default_registry
    g = default_registry().series("lsm_tablet_balance", table="tbal")
    assert g and g[0].value == pytest.approx(tm.shard_balance())
    assert st._c_tablet_splits.value > 0


# ------------------------------------------------- spmd routing equality
def test_spmd_tablet_bucketing_matches_host_map():
    """``_bucket_local_tablets`` (device operands, padded to a static max
    tablet count) must route every id to the same shard as the host
    ``TabletMap.owner_of`` — and padded split slots must never match."""
    import jax.numpy as jnp
    from repro.db.spmd import _bucket_local, _bucket_local_tablets
    from repro.kernels.common import I32_MAX

    tm = TabletMap.uniform(S, ID_CAP)
    tm.split(1, int(ID_CAP * 0.3))
    tm.move(4, 3)
    tm.split(0, 7)
    rng = np.random.default_rng(17)
    br = rng.integers(0, ID_CAP, 64).astype(np.int32)
    br[-8:] = I32_MAX  # pads route to the last shard, like _bucket_local
    bc = rng.integers(0, ID_CAP, 64).astype(np.int32)
    bv = rng.normal(size=64).astype(np.float32)
    splits, owners = tm.device_routing(max_tablets=8 * S)
    sr, sc, sv = _bucket_local_tablets(
        jnp.asarray(br), jnp.asarray(bc), jnp.asarray(bv),
        jnp.asarray(splits), jnp.asarray(owners), S)
    sr = np.asarray(sr)
    want_owner = tm.owner_of(br[:-8])
    for s in range(S):
        got = sorted(x for x in sr[s].tolist() if x != I32_MAX)
        want = sorted(br[:-8][want_owner == s].tolist())
        if s == S - 1:
            want += [I32_MAX] * 0  # pads carry I32_MAX keys: filtered
        assert got == want, s
    # uniform map must reproduce the static bucketing bit for bit
    tmu = TabletMap.uniform(S, ID_CAP)
    su, ou = tmu.device_routing(max_tablets=8 * S)
    a = _bucket_local_tablets(jnp.asarray(br), jnp.asarray(bc),
                              jnp.asarray(bv), jnp.asarray(su),
                              jnp.asarray(ou), S)
    b = _bucket_local(jnp.asarray(br), jnp.asarray(bc), jnp.asarray(bv),
                      S, ID_CAP)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- durability churn
def test_checkpoint_recover_after_split_merge_churn(tmp_path):
    """checkpoint → split/move/merge churn → crash: recovery rebuilds
    the exact map (manifest base + meta-frame replay) and the data
    differential holds against an oracle fed the same stream."""
    from repro.db.lsm.manifest import recover
    d = str(tmp_path / "db")
    st = _mk("tdur", wal_dir=d)
    oracle = _mk("tdur_oracle", dynamic=False)
    rng = np.random.default_rng(31)
    for _ in range(4):
        r, c, v = _zipf_batch(rng, 200)
        st.insert(r, c, v)
        oracle.insert(r, c, v)
    st.checkpoint()
    for _ in range(3):
        r, c, v = _zipf_batch(rng, 200)
        st.insert(r, c, v)
        oracle.insert(r, c, v)
        st.maybe_rebalance()
    tm = st.tablet_map
    if tm.n > S + 1:
        # merge one adjacent same-owner pair if any exists (post-
        # rebalance maps may interleave owners completely)
        for i in range(tm.n - 1):
            if tm.owners[i] == tm.owners[i + 1]:
                st.merge_tablet(int(tm.tablet_ids[i]))
                break
    r, c, v = _zipf_batch(rng, 200)
    st.insert(r, c, v)
    oracle.insert(r, c, v)
    want_map = st.tablet_map.to_manifest()
    st._wal.close()  # crash
    rec = recover(d)
    assert rec.tablet_map.to_manifest() == want_map
    _assert_same_triples(rec.scan(), oracle.scan())
    rec._wal.close()
