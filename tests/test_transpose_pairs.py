"""Engine-maintained transpose pairs + the ReadPlan connector API.

* Differential column-selector property: the transpose-routed fused scan,
  the on-device ``col_filter`` pushdown, and the full-scan + host-isin
  baseline must agree with a sequential dict oracle for every combiner,
  across random interleavings of ingest/flush/compact (so ranges span
  flush and compaction boundaries).
* One-dispatch structure: a column range read on a pair executes as fused
  scan dispatches on the SIBLING only — the primary's full-scan counter
  and its own scan/query dispatch counters stay flat.
* Connector surface: ``DB[t, tt]`` binds a pair backed by ONE store,
  ``put`` ingests once (engine dual-writes), checkpoint/recover restore
  both sides from one snapshot + pair-tagged WAL, ``delete``/``drop``
  release the store (leak regression).
* ``ReadPlan`` / ``StoreConfig`` round-trips and the deprecated
  ``resolve_selector`` shim.
"""
import dataclasses
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.db.connector import (ReadPlan, TablePair, TransposedView,
                                dbsetup, delete, recover_connector)
from repro.db.kvstore import COMBINERS, ShardedTable, StoreConfig
from repro.obs import default_registry

FUZZ_BUDGET = int(os.environ.get("FUZZ_BUDGET", "0"))

# one tiny fixed geometry for every example: jit caches stay warm
CFG = dict(num_shards=2, capacity_per_shard=2048, batch_cap=256,
           id_capacity=1 << 8, memtable_cap=32, l0_slots=3)


def _oracle_apply(oracle, r, c, v, combiner):
    for a, b, x in zip(r, c, v):
        k = (int(a), int(b))
        if k in oracle:
            oracle[k] = {"last": float(x), "sum": oracle[k] + float(x),
                         "min": min(oracle[k], float(x)),
                         "max": max(oracle[k], float(x))}[combiner]
        else:
            oracle[k] = float(x)


def _as_dict(r, c, v):
    return {(int(a), int(b)): float(x) for a, b, x in zip(r, c, v)}


def _check_close(got, want, label, ctx):
    assert set(got) == set(want), (label, ctx, set(got) ^ set(want))
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4, abs=1e-5), \
            (label, ctx, k, got[k], want[k])


# ------------------------------------------------ column-selector routes
@settings(max_examples=8 + FUZZ_BUDGET, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(COMBINERS),
       st.lists(st.sampled_from(["ins", "ins", "ins", "flush", "compact",
                                 "colscan", "colquery"]),
                min_size=4, max_size=12))
def test_column_selector_routes_agree(seed, combiner, ops):
    """Random ingest/flush/compact interleavings; every column read must
    return identical results from (a) the transpose-routed fused scan,
    (b) the on-device col_filter pushdown on a row-driven scan, and
    (c) the full-scan + host-isin baseline — all equal to the oracle.
    Ends by checking the sibling IS the transpose of the forward table."""
    rng = np.random.default_rng(seed)
    pair = ShardedTable(f"cprop_{combiner}", transpose=True,
                        combiner=combiner, **CFG)
    oracle = {}

    def check_colscan():
        lo = int(rng.integers(0, CFG["id_capacity"]))
        hi = min(lo + int(rng.integers(0, 64)), CFG["id_capacity"] + 4)
        want = {k: v for k, v in oracle.items() if lo <= k[1] < hi}
        ctx = (seed, combiner, lo, hi)
        routed = _as_dict(*pair.scan_col_range(lo, hi))
        ids = np.arange(lo, min(hi, CFG["id_capacity"]), dtype=np.int32)
        pushed = _as_dict(*pair.scan_range(0, CFG["id_capacity"],
                                           col_filter=ids))
        r, c, v = pair.scan()
        keep = (c >= lo) & (c < hi)
        host = _as_dict(r[keep], c[keep], v[keep])
        _check_close(routed, want, "transpose-routed", ctx)
        _check_close(pushed, want, "col_filter-pushdown", ctx)
        _check_close(host, want, "host-isin", ctx)

    def check_colquery():
        cols = np.asarray(sorted({k[1] for k in oracle}), np.int32)
        if len(cols) == 0:
            return
        pick = rng.choice(cols, size=min(8, len(cols)), replace=False)
        absent = rng.integers(0, CFG["id_capacity"], 2).astype(np.int32)
        q = np.unique(np.concatenate([pick, absent])).astype(np.int32)
        want = {k: v for k, v in oracle.items() if k[1] in set(q.tolist())}
        ctx = (seed, combiner, q.tolist())
        routed = _as_dict(*pair.query_cols(q))
        pushed = _as_dict(*pair.scan_range(0, CFG["id_capacity"],
                                           col_filter=q))
        _check_close(routed, want, "query_cols", ctx)
        _check_close(pushed, want, "col_filter-pushdown", ctx)

    for op in ops:
        if op == "ins":
            n = int(rng.integers(1, 24))
            r = rng.integers(0, CFG["id_capacity"], n).astype(np.int32)
            c = rng.integers(0, CFG["id_capacity"], n).astype(np.int32)
            v = rng.integers(-4, 5, n).astype(np.float32)
            pair.insert(r, c, v)
            _oracle_apply(oracle, r, c, v, combiner)
        elif op == "flush":
            pair.flush()
        elif op == "compact":
            pair.major_compact()
        elif op == "colscan":
            check_colscan()
        else:
            check_colquery()
    check_colscan()
    # the sibling is EXACTLY the transpose of the forward table
    fwd = _as_dict(*pair.scan())
    sib = _as_dict(*pair.t_store.scan())
    _check_close(sib, {(b, a): v for (a, b), v in fwd.items()},
                 "sibling-transpose", (seed, combiner))
    pair.close()


def test_col_range_read_is_one_sibling_dispatch():
    """Structural acceptance: a column range read on a pair serves from
    the transpose sibling's fused scan — sibling scan dispatches move,
    while the primary's full-scan counter, the primary's own dispatch
    counters, and the sibling's point-query path ALL stay flat."""
    reg = default_registry()
    st = ShardedTable("onedisp", transpose=True, combiner="last", **CFG)
    rng = np.random.default_rng(5)
    for _ in range(6):
        r = rng.integers(0, CFG["id_capacity"], 24).astype(np.int32)
        c = rng.integers(0, CFG["id_capacity"], 24).astype(np.int32)
        st.insert(r, c, rng.normal(size=24).astype(np.float32))
    st.flush()
    st.scan_col_range(10, 90)  # warm the compiled path

    def snap():
        full = sum(x.value for x in reg.series("db_full_scans",
                                               table="onedisp"))
        return (full,
                st.engine_stats()["scan_dispatches"],
                st.engine_stats()["fused_dispatches"],
                st.t_store.engine_stats()["scan_dispatches"],
                st.t_store.engine_stats()["fused_dispatches"])

    before = snap()
    r, c, v = st.scan_col_range(10, 90)
    assert len(r) > 0
    after = snap()
    assert after[0] == before[0], "column read fell back to a full scan"
    assert after[1] == before[1], "primary scan path dispatched"
    assert after[2] == before[2], "primary point-query path dispatched"
    sib_scans = after[3] - before[3]
    assert 1 <= sib_scans <= CFG["num_shards"], sib_scans
    assert after[4] == before[4], "sibling point-query path dispatched"
    st.close()


def test_empty_col_filter_short_circuits():
    st = ShardedTable("emptyf", transpose=True, combiner="last", **CFG)
    st.insert(np.asarray([1, 2], np.int32), np.asarray([3, 4], np.int32),
              np.asarray([1.0, 2.0], np.float32))
    r, c, v = st.scan_range(0, CFG["id_capacity"],
                            col_filter=np.zeros(0, np.int32))
    assert len(r) == len(c) == len(v) == 0
    r, c, v = st.query_rows(np.asarray([1, 2], np.int32),
                            col_filter=np.zeros(0, np.int32))
    assert len(r) == 0
    st.close()


def test_insert_routed_rejected_on_pair():
    st = ShardedTable("irpair", transpose=True, combiner="last", **CFG)
    with pytest.raises(ValueError, match="sibling"):
        st.insert_routed(np.asarray([1], np.int32),
                         np.asarray([2], np.int32),
                         np.asarray([1.0], np.float32))
    st.close()


# ------------------------------------------------------ connector surface
def _server(**kw):
    conf = dict(num_shards=2, capacity_per_shard=2048, batch_cap=256,
                id_capacity=1 << 10)
    conf.update(kw)
    return dbsetup("tp", conf)


def _put_demo(pair, n=60, seed=0):
    rng = np.random.default_rng(seed)
    rows = np.asarray([f"e{i:03d}" for i in rng.integers(0, 30, n)], object)
    cols = np.asarray([f"v{i:03d}" for i in rng.integers(0, 30, n)], object)
    vals = rng.integers(1, 9, n).astype(float)
    pair.put_triple(rows, cols, vals)
    return rows, cols, vals


def test_pair_binding_is_one_store_and_routes_columns():
    DB = _server()
    E = DB["edges", "edgesT"]
    assert isinstance(E, TablePair)
    assert isinstance(DB.tables["edgesT"], TransposedView)
    _put_demo(E)
    store = E.table.store
    assert store.t_store is not None
    # ONE ingest, engine dual-writes: sibling mirrors the primary exactly
    assert store.nnz() == store.t_store.nnz() == E.nnz()
    # column range read via the pair == via the view == host oracle
    full = _as_str_set(E[:, :])
    want = {(r, c, v) for r, c, v in full if "v005" <= c <= "v015"}
    got = _as_str_set(E[:, "v005,:,v015,"])
    assert got == want
    got_view = _as_str_set(DB.tables["edgesT"]["v005,:,v015,", :])
    assert got_view == {(c, r, v) for r, c, v in want}
    # re-binding the same pair returns the same underlying table
    E2 = DB["edges", "edgesT"]
    assert E2.table is E.table
    # metrics: pair reported once, sibling nested under "transpose"
    m = DB.metrics()
    assert "transpose" in m["tables"]["edges"]
    assert m["tables"]["edges"]["transpose"]["sibling"] == "edges@T"
    assert "edgesT" not in m["tables"]
    delete(E)


def _as_str_set(assoc):
    r, c, v = assoc.triples()
    return {(str(a), str(b), float(x)) for a, b, x in zip(r, c, v)}


def test_rebinding_single_table_as_pair_raises():
    DB = _server()
    DB["solo"]
    with pytest.raises(ValueError, match="transpose"):
        DB["solo", "soloT"]
    DB.drop("solo")


def test_pair_checkpoint_and_recovery(tmp_path):
    """One checkpoint covers both sides; recovery by the (name, name_t)
    tuple rebuilds the pair — including post-checkpoint batches that live
    only as pair-tagged WAL records — and column routing still works."""
    d = str(tmp_path / "wal_root")
    DB = dbsetup("durpair", dict(num_shards=2, capacity_per_shard=2048,
                                 batch_cap=256, id_capacity=1 << 10,
                                 wal_root=d))
    E = DB["edges", "edgesT"]
    _put_demo(E, seed=1)
    E.checkpoint()
    E.put_triple(np.asarray(["zz"], object), np.asarray(["yy"], object),
                 np.asarray([42.0]))
    want = _as_str_set(E[:, :])
    want_col = _as_str_set(E[:, "v005,:,v015,"]) | {("zz", "yy", 42.0)} \
        if "v005" <= "yy" <= "v015" else _as_str_set(E[:, "v005,:,v015,"])
    del E, DB  # crash
    DB2, E2 = recover_connector(d, ("edges", "edgesT"))
    assert isinstance(E2, TablePair)
    store = E2.table.store
    assert store.t_store is not None
    assert store.nnz() == store.t_store.nnz()
    assert _as_str_set(E2[:, :]) == want
    assert _as_str_set(E2[:, "v005,:,v015,"]) == want_col
    # recovering a pair-checkpointed table by its single name still works
    del E2, DB2
    DB3, T3 = recover_connector(d, "edges")
    assert _as_str_set(T3[:, :]) == want
    # ...but tuple recovery of a non-pair table must refuse
    T4 = DB3["plain"]
    T4.put_triple(np.asarray(["a"], object), np.asarray(["b"], object),
                  np.asarray([1.0]))
    T4.checkpoint()
    del T4, DB3
    with pytest.raises(ValueError, match="pair"):
        recover_connector(d, ("plain", "plainT"))


def test_delete_pair_and_drop_release_the_store():
    DB = _server()
    E = DB["e", "eT"]
    _put_demo(E, n=10)
    store = E.table.store
    sib = store.t_store
    delete(E)
    assert store._closed and sib._closed
    assert DB.ls() == []
    with pytest.raises(RuntimeError):
        E.nnz()
    # drop() releases single-table stores too (old pop-only drop leaked
    # the device memtables and WAL handle)
    T = DB["solo"]
    st = T.store
    DB.drop("solo")
    assert st._closed and T._deleted
    # double-delete stays a no-op
    DB.drop("solo")
    delete(T)


# ------------------------------------------------- ReadPlan / StoreConfig
def test_read_plan_kinds_and_filter_ids():
    DB = _server()
    DB.encode_keys(np.asarray([f"k{i:02d}" for i in range(10)], object))
    assert DB.resolve_selector_plan(":").kind == "all"
    assert DB.resolve_selector_plan(None, axis="col").axis == "col"
    p = DB.resolve_selector_plan("k02,k05,")
    assert p.kind == "ids" and sorted(p.ids.tolist()) == [2, 5]
    r = DB.resolve_selector_plan("k02,:,k05,")
    assert (r.kind, r.lo, r.hi, r.filter) == ("range", 2, 6, None)
    assert r.filter_ids().tolist() == [2, 3, 4, 5]
    pre = DB.resolve_selector_plan("k0*,")
    assert pre.kind == "range" and (pre.lo, pre.hi) == (0, 10)
    route = r.with_route("transpose")
    assert route.route == "transpose" and r.route == "native"
    missing = DB.resolve_selector_plan("nope,")
    assert missing.kind == "ids" and len(missing.ids) == 0


def test_resolve_selector_shim_warns_and_matches_plan():
    DB = _server()
    DB.encode_keys(np.asarray(["a", "b", "c"], object))
    with pytest.warns(DeprecationWarning):
        ids = DB.resolve_selector("a,c,")
    assert sorted(ids.tolist()) == [0, 2]
    with pytest.warns(DeprecationWarning):
        assert DB.resolve_selector(":") is None


def test_store_config_roundtrip_and_overrides():
    cfg = StoreConfig(num_shards=3, l0_slots=5, transpose=True,
                      memtable_cap=128)
    rt = StoreConfig.from_manifest(dataclasses.asdict(cfg))
    assert rt == cfg
    # legacy manifest: mem_cap maps in, unknown per-table keys ignored
    legacy = {"num_shards": 2, "mem_cap": 99, "combiner": "sum",
              "bloom_bits_per_key": [8]}
    rt2 = StoreConfig.from_manifest(legacy)
    assert rt2.num_shards == 2 and rt2.memtable_cap == 99
    # kwargs still override the shared config at every layer
    DB = dbsetup("cfg", dict(config=StoreConfig(num_shards=2),
                             num_shards=4, fanout=8))
    assert DB.num_shards == 4 and DB.config.fanout == 8
    st = ShardedTable("cfgtab", config=DB.config, num_shards=8)
    assert st.S == 8 and st.config.num_shards == 8
    st.close()
    with pytest.raises(TypeError):
        DB.config.replace(not_a_field=1)


def test_transpose_requires_lsm_engine():
    with pytest.raises(ValueError, match="lsm"):
        ShardedTable("bad", engine="single", transpose=True,
                     num_shards=1, capacity_per_shard=512,
                     batch_cap=64, id_capacity=1 << 8)
