"""Crash-recovery fuzzing and bloom-filter sizing tests.

* WAL truncation fuzz: the log is cut at EVERY byte offset inside the
  tail frame (plus a random sample of offsets across the whole file);
  ``recover()`` must always restore a prefix-consistent store — exactly
  the batches whose records are fully intact below the cut — and a write
  made after recovery must survive a SECOND simulated crash.
* Bloom sizing: per-level ``bits_per_key``/``n_hashes`` plumb through
  build, probe, fused reads, and the snapshot manifest; measured
  false-positive rates on a fig4-shaped (power-law) key population stay
  within the theoretical bound.
"""
import json
import os
import shutil

import numpy as np
import pytest

from repro.db.kvstore import ShardedTable
from repro.db.lsm import recover
from repro.db.lsm.bloom import (bloom_build, bloom_maybe_contains,
                                num_words, suggest_hashes,
                                theoretical_fp_rate)
from repro.kernels.common import I32_MAX

# ----------------------------------------------------------- WAL fuzzing
BATCH_N = 4          # triples per batch -> 8 + 12*4 = 56-byte records
N_PRE, N_POST = 3, 3  # batches before / after the checkpoint

# weekly CI deep lane: FUZZ_BUDGET=N widens the random-offset sample (and
# tightens the second-crash cadence) by that much
FUZZ_BUDGET = int(os.environ.get("FUZZ_BUDGET", "0"))


def _build_wal_dir(root):
    """A checkpointed store plus post-checkpoint WAL-only batches.

    Returns (dir, batches, record_ends, ckpt_offset): ``record_ends[i]``
    is the byte offset just past post-checkpoint batch i's WAL record.
    """
    d = os.path.join(root, "db")
    st = ShardedTable("fz", num_shards=1, capacity_per_shard=512,
                      batch_cap=64, id_capacity=1 << 9, combiner="last",
                      memtable_cap=16, engine="lsm", wal_dir=d)
    rng = np.random.default_rng(42)
    batches = []

    def put():
        r = rng.choice(1 << 9, BATCH_N, replace=False).astype(np.int32)
        c = rng.integers(0, 4, BATCH_N).astype(np.int32)
        v = rng.normal(size=BATCH_N).astype(np.float32)
        st.insert(r, c, v)
        batches.append((r, c, v))
        return st._wal.tell()

    for _ in range(N_PRE):
        put()
    st.checkpoint()
    ckpt_off = st._wal.tell()
    ends = [put() for _ in range(N_POST)]
    st._wal.close()  # simulated crash: no further flushes
    return d, batches, ends, ckpt_off


def _expected_rows(batches, ends, ckpt_off, cut):
    """Prefix-consistent oracle: checkpointed batches always survive;
    a post-checkpoint batch survives iff its whole record is below the
    cut (replay stops at the first torn record, and records are
    sequential, so the survivors are exactly a prefix)."""
    n_ok = sum(1 for e in ends if e <= max(cut, ckpt_off))
    out = {}
    for r, c, v in batches[:N_PRE + n_ok]:
        for a, b, x in zip(r, c, v):
            out[(int(a), int(b))] = float(x)  # combiner == last
    return out


def _scan_dict(st):
    r, c, v = st.scan()
    return {(int(a), int(b)): float(x) for a, b, x in zip(r, c, v)}


def test_wal_truncation_fuzz(tmp_path):
    src, batches, ends, ckpt_off = _build_wal_dir(str(tmp_path))
    wal = os.path.join(src, "wal.log")
    size = os.path.getsize(wal)
    tail_start = ends[-2]  # every byte of the final record's frame
    rng = np.random.default_rng(7)
    sampled = sorted(set(int(x) for x in
                         rng.integers(0, tail_start,
                                      12 + FUZZ_BUDGET)))  # incl. header
    cuts = sampled + list(range(tail_start, size + 1))
    second_crash_every = 3 if FUZZ_BUDGET else 6
    for i, cut in enumerate(cuts):
        d = str(tmp_path / f"cut{cut}")
        shutil.copytree(src, d)
        with open(os.path.join(d, "wal.log"), "r+b") as f:
            f.truncate(cut)
        st = recover(d)
        want = _expected_rows(batches, ends, ckpt_off, cut)
        got = _scan_dict(st)
        assert got == pytest.approx(want), (cut, sorted(got), sorted(want))
        if i % second_crash_every == 0:
            # post-recovery write must survive a SECOND crash (recovery
            # truncated the torn tail, so the new record is replayable)
            st.insert(np.asarray([500], np.int32), np.asarray([0], np.int32),
                      np.asarray([9.5], np.float32))
            st._wal.close()
            st2 = recover(d)
            got2 = _scan_dict(st2)
            want2 = dict(want)
            want2[(500, 0)] = 9.5
            assert got2 == pytest.approx(want2), (cut, sorted(got2))
            st2._wal.close()
        st._wal.close()


def _build_pair_wal_dir(root):
    """Same shape as ``_build_wal_dir`` but for an engine-maintained
    transpose PAIR: each ``insert`` writes ONE pair-tagged WAL record and
    lands in both sibling shard sets."""
    d = os.path.join(root, "pdb")
    st = ShardedTable("fzp", num_shards=1, capacity_per_shard=512,
                      batch_cap=64, id_capacity=1 << 9, combiner="last",
                      memtable_cap=16, engine="lsm", wal_dir=d,
                      transpose=True)
    rng = np.random.default_rng(42)
    batches = []

    def put():
        r = rng.choice(1 << 9, BATCH_N, replace=False).astype(np.int32)
        c = rng.integers(0, 4, BATCH_N).astype(np.int32)
        v = rng.normal(size=BATCH_N).astype(np.float32)
        st.insert(r, c, v)
        batches.append((r, c, v))
        return st._wal.tell()

    for _ in range(N_PRE):
        put()
    st.checkpoint()
    ckpt_off = st._wal.tell()
    ends = [put() for _ in range(N_POST)]
    st._wal.close()  # simulated crash
    return d, batches, ends, ckpt_off


def test_wal_pair_truncation_fuzz(tmp_path):
    """Pair atomicity under crash: cut the WAL at EVERY byte offset of the
    tail frame (plus sampled offsets across the file); recovery must
    restore the forward table to the prefix-consistent oracle AND the
    transpose sibling to EXACTLY the transpose of the forward table — both
    sides of each pair-tagged record commit or vanish together, never
    half. A post-recovery pair write must survive a second crash."""
    src, batches, ends, ckpt_off = _build_pair_wal_dir(str(tmp_path))
    wal = os.path.join(src, "wal.log")
    size = os.path.getsize(wal)
    tail_start = ends[-2]
    rng = np.random.default_rng(11)
    sampled = sorted(set(int(x) for x in
                         rng.integers(0, tail_start, 8 + FUZZ_BUDGET)))
    cuts = sampled + list(range(tail_start, size + 1))
    for i, cut in enumerate(cuts):
        d = str(tmp_path / f"pcut{cut}")
        shutil.copytree(src, d)
        with open(os.path.join(d, "wal.log"), "r+b") as f:
            f.truncate(cut)
        st = recover(d)
        assert st.t_store is not None  # manifest config carries the pair
        want = _expected_rows(batches, ends, ckpt_off, cut)
        got = _scan_dict(st)
        assert got == pytest.approx(want), (cut, sorted(got), sorted(want))
        sib = _scan_dict(st.t_store)
        assert sib == pytest.approx(
            {(b, a): v for (a, b), v in want.items()}), (cut, sorted(sib))
        if i % 6 == 0:
            st.insert(np.asarray([500], np.int32), np.asarray([2], np.int32),
                      np.asarray([9.5], np.float32))
            st._wal.close()
            st2 = recover(d)
            want2 = dict(want)
            want2[(500, 2)] = 9.5
            assert _scan_dict(st2) == pytest.approx(want2), cut
            assert _scan_dict(st2.t_store) == pytest.approx(
                {(b, a): v for (a, b), v in want2.items()}), cut
            st2._wal.close()
        st._wal.close()


def test_wal_pair_record_is_single_frame(tmp_path):
    """One pair ingest = ONE WAL record (payload logged once, transpose
    derived at replay) — the pair log is byte-for-byte the same size as a
    single-table log over the same batches, except the flag bit."""
    from repro.db.lsm.wal import WriteAheadLog

    single, _, _, _ = _build_wal_dir(str(tmp_path))
    pair, _, _, _ = _build_pair_wal_dir(str(tmp_path))
    s_wal, p_wal = os.path.join(single, "wal.log"), os.path.join(pair,
                                                                 "wal.log")
    assert os.path.getsize(s_wal) == os.path.getsize(p_wal)
    tags = [p for *_abc, p in WriteAheadLog.replay(p_wal, tagged=True)]
    assert tags and all(tags)  # every frame carries the pair flag
    tags_s = [p for *_abc, p in WriteAheadLog.replay(s_wal, tagged=True)]
    assert tags_s and not any(tags_s)


def test_wal_header_corruption_keeps_post_recovery_writes(tmp_path):
    """A crash that tears the WAL HEADER itself must not poison the log:
    recovery keeps the snapshot, re-anchors the manifest offset, lays a
    fresh header, and a post-recovery write survives the next crash
    (regression: appends after header garbage were unreplayable)."""
    src, batches, ends, ckpt_off = _build_wal_dir(str(tmp_path))
    for cut in (0, 3, 7):
        d = str(tmp_path / f"hdr{cut}")
        shutil.copytree(src, d)
        with open(os.path.join(d, "wal.log"), "r+b") as f:
            f.truncate(cut)
        st = recover(d)
        want = _expected_rows(batches, ends, ckpt_off, cut)
        assert _scan_dict(st) == pytest.approx(want), cut
        st.insert(np.asarray([501], np.int32), np.asarray([0], np.int32),
                  np.asarray([7.5], np.float32))
        st._wal.close()
        st2 = recover(d)
        want[(501, 0)] = 7.5
        assert _scan_dict(st2) == pytest.approx(want), cut
        st2._wal.close()


def test_wal_mid_file_corruption_stops_replay_cleanly(tmp_path):
    """Flipping bytes INSIDE an early record (not just truncating) must
    drop that record and everything after it — CRC framing, not length
    trust."""
    src, batches, ends, ckpt_off = _build_wal_dir(str(tmp_path))
    d = str(tmp_path / "corrupt")
    shutil.copytree(src, d)
    wal = os.path.join(d, "wal.log")
    with open(wal, "r+b") as f:  # corrupt the payload of post-ckpt batch 1
        f.seek(ends[0] + 12)
        f.write(b"\xff\xff\xff")
    st = recover(d)
    want = _expected_rows(batches, ends, ckpt_off, ends[0])
    assert _scan_dict(st) == pytest.approx(want)
    st._wal.close()


# ------------------------------------- tablet split-boundary crash fuzz
def _build_tablet_wal_dir(root):
    """Dynamic-tablet transpose PAIR whose post-checkpoint WAL interleaves
    tablet-tagged pair data frames (bits 31+30), a SPLIT meta frame, and a
    MOVE meta frame (bit 29). Returns everything the truncation oracle
    needs: the dir, the last-wins dict of checkpointed triples, the
    checkpoint offset, and the [win_lo, win_hi) byte window bracketing the
    split/move frame sequence."""
    d = os.path.join(root, "tdb")
    st = ShardedTable("fzt", num_shards=2, capacity_per_shard=1024,
                      batch_cap=64, id_capacity=1 << 9, combiner="last",
                      memtable_cap=64, engine="lsm", wal_dir=d,
                      transpose=True, dynamic_tablets=True)
    rng = np.random.default_rng(42)
    base = {}

    def put():
        r = rng.choice(1 << 9, BATCH_N, replace=False).astype(np.int32)
        c = rng.integers(0, 4, BATCH_N).astype(np.int32)
        v = rng.normal(size=BATCH_N).astype(np.float32)
        st.insert(r, c, v)
        return r, c, v

    for _ in range(N_PRE):
        for a, b, x in zip(*put()):
            base[(int(a), int(b))] = float(x)
    st.checkpoint()
    ckpt_off = st._wal.tell()
    put()
    win_lo = st._wal.tell()
    new_id = st.split_tablet()  # hottest tablet, fence-median key
    assert new_id is not None
    put()
    cur = int(st.tablet_map.owners[st.tablet_map.index_of(new_id)])
    assert st.move_tablet(new_id, 1 - cur)
    put()
    win_hi = st._wal.tell()
    put()  # one frame past the window: replay must resume cleanly after it
    st._wal.close()  # crash
    return d, base, ckpt_off, win_lo, win_hi


def _tablet_frame_oracle(wal_path, ckpt_off, base_rows, tablet_filter=None):
    """Reference replay: walk the intact post-checkpoint frames of a (cut)
    log and apply them to a plain dict + TabletMap — no engine, no
    migration, no memtable. ``recover`` must land on the same map and the
    same triples however its snapshot/migration machinery gets there."""
    from repro.db.lsm.wal import WriteAheadLog
    from repro.db.tablets import TabletMap

    tm = TabletMap.uniform(2, 1 << 9)
    rows = dict(base_rows)
    for item in WriteAheadLog.replay_full(wal_path, start=ckpt_off):
        if item[0] == "meta":
            op = item[1]
            if op["op"] == "split":
                tm.split(op["tablet"], op["key"], new_id=op["new"])
            elif op["op"] == "move":
                tm.move(op["tablet"], op["to"])
            else:
                tm.merge(op["tablet"])
            continue
        _, tid, r, c, v, pair = item
        assert pair and tid is not None  # every data frame tagged, paired
        if tablet_filter is not None and tid not in tablet_filter:
            continue
        for a, b, x in zip(r, c, v):
            rows[(int(a), int(b))] = float(x)
    return tm, rows


def test_wal_tablet_split_boundary_truncation_fuzz(tmp_path):
    """Cut the WAL at EVERY byte across the frame window holding a tablet
    split and a tablet move (plus the tail frame and sampled earlier
    offsets; FUZZ_BUDGET sweeps every post-checkpoint byte): recovery must
    restore the tablet map to exactly the meta-frame prefix below the cut
    AND the data to the intact-frame prefix — with the transpose sibling
    staying exactly the transpose throughout."""
    src, base, ckpt_off, win_lo, win_hi = _build_tablet_wal_dir(
        str(tmp_path))
    wal = os.path.join(src, "wal.log")
    size = os.path.getsize(wal)
    if FUZZ_BUDGET:
        cuts = list(range(ckpt_off, size + 1))
    else:
        rng = np.random.default_rng(13)
        sampled = sorted(set(int(x) for x in
                             rng.integers(ckpt_off, win_lo, 6)))
        cuts = sorted(set(sampled + list(range(win_lo - 4, win_hi + 1))
                          + list(range(win_hi, size + 1, 5)) + [size]))
    for cut in cuts:
        d = str(tmp_path / f"tcut{cut}")
        shutil.copytree(src, d)
        with open(os.path.join(d, "wal.log"), "r+b") as f:
            f.truncate(cut)
        want_tm, want = _tablet_frame_oracle(os.path.join(d, "wal.log"),
                                             ckpt_off, base)
        st = recover(d)
        assert st.tablet_map.to_manifest() == want_tm.to_manifest(), cut
        assert _scan_dict(st) == pytest.approx(want), cut
        assert _scan_dict(st.t_store) == pytest.approx(
            {(b, a): v for (a, b), v in want.items()}), cut
        st._wal.close()


def test_wal_tablet_filtered_replay_per_tablet_suffix(tmp_path):
    """Distributed-recovery contract: ``recover(d, tablet_filter=[t])``
    restores the FULL tablet map (meta frames always apply) but replays
    ONLY frames tagged ``t`` — for every tablet in the final map, the
    filtered store holds the snapshot plus exactly that tablet's suffix,
    and a post-recovery write into the filtered table stays readable."""
    src, base, ckpt_off, _win_lo, _win_hi = _build_tablet_wal_dir(
        str(tmp_path))
    wal = os.path.join(src, "wal.log")
    full_tm, _ = _tablet_frame_oracle(wal, ckpt_off, base)
    for tid in full_tm.tablet_ids.tolist():
        d = str(tmp_path / f"tf{tid}")
        shutil.copytree(src, d)
        st = recover(d, tablet_filter=[tid])
        assert st.tablet_map.to_manifest() == full_tm.to_manifest(), tid
        _, want = _tablet_frame_oracle(wal, ckpt_off, base,
                                       tablet_filter={tid})
        assert _scan_dict(st) == pytest.approx(want), tid
        assert _scan_dict(st.t_store) == pytest.approx(
            {(b, a): v for (a, b), v in want.items()}), tid
        st.insert(np.asarray([500], np.int32), np.asarray([3], np.int32),
                  np.asarray([6.5], np.float32))
        r, _c, v = st.query_rows(np.asarray([500], np.int32))
        assert r.tolist() == [500] and v[0] == pytest.approx(6.5)
        st._wal.close()


# ------------------------------------------------- dictionary durability
def test_connector_recovery_restores_string_queries(tmp_path):
    """The StringDicts persist alongside the snapshot manifest (checkpoint
    snapshot + append journal), so ``recover_connector`` restores
    string-keyed queries — including keys interned AFTER the last
    checkpoint, and string VALUES — and stays durable through a second
    crash."""
    from repro.db import dbsetup, recover_connector

    d = str(tmp_path / "wal_root")
    DB = dbsetup("durdb", dict(num_shards=2, capacity_per_shard=2048,
                               batch_cap=256, id_capacity=1 << 12,
                               wal_root=d))
    T = DB["edges"]
    T.put_triple(np.asarray(["a", "b"], object),
                 np.asarray(["x", "y"], object), np.asarray([1.0, 2.0]))
    T.checkpoint()
    # post-checkpoint: new string keys live only in the dict journal
    T.put_triple(np.asarray(["c"], object), np.asarray(["z"], object),
                 np.asarray([3.0]))
    want = {("a", "x", 1.0), ("b", "y", 2.0), ("c", "z", 3.0)}
    del T, DB  # crash
    DB2, T2 = recover_connector(d, "edges")
    got = T2["a,b,c,", :]
    assert {(r, c, float(v)) for r, c, v in zip(*got.triples())} == want
    # recovered connector stays writable + durable through a SECOND crash
    T2.put_triple(np.asarray(["d"], object), np.asarray(["w"], object),
                  np.asarray([4.0]))
    del T2, DB2
    DB3, T3 = recover_connector(d, "edges")
    r, c, v = T3["d,", :].triples()
    assert (list(r), list(c), list(v)) == (["d"], ["w"], [4.0])
    # string VALUES round-trip via the per-table valdict
    T4 = DB3["svals"]
    T4.put_triple(np.asarray(["p"], object), np.asarray(["q"], object),
                  np.asarray(["hello"], object))
    T4.checkpoint()
    del T4, DB3
    _, T5 = recover_connector(d, "svals")
    assert list(T5["p,", :].triples()[2]) == ["hello"]


def test_dict_checkpoint_crash_window_keeps_ids_stable(tmp_path):
    """Crash BETWEEN the dict checkpoint's snapshot write and its journal
    reset: the journal still holds strings the snapshot already covers;
    replay must dedup them or every later id shifts and string queries go
    silently empty (regression)."""
    from repro.db import dbsetup, recover_connector

    d = str(tmp_path / "wal_root")
    DB = dbsetup("durdb2", dict(num_shards=1, capacity_per_shard=1024,
                                batch_cap=128, id_capacity=1 << 10,
                                wal_root=d))
    T = DB["t"]
    T.put_triple(np.asarray(["a", "b"], object),
                 np.asarray(["x", "y"], object), np.asarray([1.0, 2.0]))
    log = os.path.join(d, "keydict.log")
    with open(log, encoding="utf-8") as f:
        pre_ckpt_log = f.read()  # entries about to be snapshotted
    T.checkpoint()
    T.put_triple(np.asarray(["c"], object), np.asarray(["z"], object),
                 np.asarray([3.0]))
    del T, DB  # crash — then rewrite the journal to the torn-checkpoint
    # shape: snapshot written but journal never reset, so it still leads
    # with entries the snapshot already covers
    with open(log, encoding="utf-8") as f:
        post = f.read()
    with open(log, "w", encoding="utf-8") as f:
        f.write(pre_ckpt_log + post)
    DB2, T2 = recover_connector(d, "t")
    got = T2["a,b,c,", :]
    assert {(r, c, float(v)) for r, c, v in zip(*got.triples())} == \
        {("a", "x", 1.0), ("b", "y", 2.0), ("c", "z", 3.0)}


# ----------------------------------------------------------- bloom sizing
def _fig4_keys(n, id_cap=1 << 20, seed=0):
    """Power-law row ids, the fig4 workload shape (graph500-style hubs)."""
    rng = np.random.default_rng(seed)
    raw = (rng.pareto(1.2, n) * (id_cap // 64)).astype(np.int64)
    return np.unique(np.clip(raw, 0, id_cap - 1).astype(np.int32))


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_bloom_fp_rate_within_theoretical_bound(bits):
    keys = _fig4_keys(4000)[:2000]
    cap = 2048
    rows = np.full(cap, I32_MAX, np.int32)
    rows[:len(keys)] = np.sort(keys)
    w = num_words(cap, bits)
    h = suggest_hashes(bits)
    words = np.asarray(bloom_build(rows, w, h))
    assert np.asarray(bloom_maybe_contains(words, keys, h)).all(), \
        "bloom false negative"
    universe = np.arange(1 << 20, dtype=np.int32)
    absent = np.setdiff1d(
        np.random.default_rng(1).choice(universe, 60000, replace=False),
        keys)[:40000]
    fp = float(np.asarray(bloom_maybe_contains(words, absent, h)).mean())
    bound = theoretical_fp_rate(len(keys), w, h)
    # xor-shift hashes are not ideal hashes; allow 2x + absolute slack
    assert fp <= 2.0 * bound + 0.01, (bits, fp, bound)


def test_bloom_more_bits_fewer_false_positives():
    keys = _fig4_keys(4000)[:2000]
    cap = 2048
    rows = np.full(cap, I32_MAX, np.int32)
    rows[:len(keys)] = np.sort(keys)
    absent = np.setdiff1d(
        np.random.default_rng(2).integers(0, 1 << 20, 60000).astype(np.int32),
        keys)[:40000]
    rates = []
    for bits in (2, 8, 16):
        w, h = num_words(cap, bits), suggest_hashes(bits)
        words = np.asarray(bloom_build(rows, w, h))
        rates.append(
            float(np.asarray(bloom_maybe_contains(words, absent, h)).mean()))
    assert rates[0] > rates[1] > rates[2], rates
    assert rates[2] < 0.01, rates


def test_per_level_bloom_sizing_plumbs_through_engine(tmp_path):
    """(8, 12, 16) bits/key with per-level hash counts: deeper levels get
    denser filters; reads stay exact through flush/compaction AND through
    a snapshot/recover round-trip (manifest records the sizing)."""
    d = str(tmp_path / "db")
    st = ShardedTable("sz", num_shards=1, capacity_per_shard=4096,
                      batch_cap=256, id_capacity=1 << 10, combiner="sum",
                      memtable_cap=64, engine="lsm", wal_dir=d,
                      bloom_bits_per_key=(8, 12, 16),
                      bloom_hashes=(4, 6, 8))
    runs = st._runs
    assert runs.bloom_bits[0] == 8 and runs.bloom_bits[-1] == 16
    assert runs.levels[-1]["hashes"] == 8
    # deeper level, denser filter (words scale with bits at equal cap):
    same_cap = {}
    for lv in runs.levels:
        same_cap.setdefault(lv["cap"], []).append(lv["words"])
    assert runs.levels[-1]["words"] == num_words(runs.levels[-1]["cap"], 16)
    rng = np.random.default_rng(3)
    oracle = {}
    for _ in range(20):
        r = rng.integers(0, 1 << 10, 48).astype(np.int32)
        c = rng.integers(0, 4, 48).astype(np.int32)
        v = rng.normal(size=48).astype(np.float32)
        st.insert(r, c, v)
        for a, b, x in zip(r, c, v):
            oracle[(int(a), int(b))] = oracle.get((int(a), int(b)), 0.0) \
                + float(x)
    assert st.engine_stats()["major_compactions"] >= 1
    q = np.unique([k[0] for k in oracle])[:40].astype(np.int32)
    got = {(int(a), int(b)): float(x)
           for a, b, x in zip(*st.query_rows(q))}
    want = {k: v for k, v in oracle.items() if k[0] in set(q.tolist())}
    assert set(got) == set(want)
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4)
    # sizing survives crash recovery via the manifest
    st.checkpoint()
    with open(os.path.join(d, "MANIFEST.json")) as f:
        man = json.load(f)
    assert man["config"]["bloom_bits_per_key"] == list(runs.bloom_bits)
    st._wal.close()
    rec = recover(d)
    assert rec._runs.bloom_bits == runs.bloom_bits
    assert rec._runs.bloom_hashes == runs.bloom_hashes
    got2 = {(int(a), int(b)): float(x)
            for a, b, x in zip(*rec.query_rows(q))}
    assert got2 == pytest.approx(got)
