"""Server-side GraphBLAS ops (the paper's §VI future work) vs dense oracles."""
import numpy as np
import pytest

from repro.core import Assoc
from repro.db import dbsetup
from repro.db.graphulo import table_spgemm, table_spmv, table_tricount


@pytest.fixture
def setup():
    server = dbsetup("graphulo", num_shards=2, capacity_per_shard=4096,
                     batch_cap=2048, id_capacity=1 << 12)
    rng = np.random.default_rng(5)
    n, nnz = 12, 40
    rows = np.asarray([f"v{i:02d}" for i in rng.integers(0, n, nnz)], object)
    cols = np.asarray([f"v{i:02d}" for i in rng.integers(0, n, nnz)], object)
    vals = rng.integers(1, 5, nnz).astype(np.float64)
    t = server["A", "AT"]
    t.put_triple(rows, cols, vals)
    # dense oracle over the interned universe
    dim = len(server.keydict)
    dense = np.zeros((dim, dim))
    a = Assoc(rows, cols, vals, func="last")
    for r, c, v in zip(*a.triples()):
        dense[server.keydict.get(r), server.keydict.get(c)] = v
    return server, t, dense


def test_spmv_matches_dense(setup):
    server, t, dense = setup
    x = np.arange(dense.shape[0], dtype=np.float64)
    got = table_spmv(t, x)
    np.testing.assert_allclose(got, dense @ x)


def test_spmv_pallas_path(setup):
    server, t, dense = setup
    x = np.ones(dense.shape[0])
    got = table_spmv(t, x, use_pallas=True)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-5)


def test_spgemm_matches_dense_and_lands_in_table(setup):
    server, t, dense = setup
    out = table_spgemm(t, t, server, out_name="A2")
    d2 = dense @ dense
    got = np.zeros_like(d2)
    r, c, v = out[:, :].triples()
    for rr, cc, vv in zip(r, c, v):
        got[server.keydict.get(rr), server.keydict.get(cc)] = vv
    np.testing.assert_allclose(got, d2)
    # the result table is Listing-1 queryable
    nz = np.nonzero(d2.sum(axis=1))[0]
    key = server.keydict.decode(nz[:1])[0]
    assert out[str(key) + ",", :].nnz() > 0


def test_triangle_count_matches_oracle(setup):
    server, t, dense = setup
    a = ((dense + dense.T) > 0).astype(np.float64)
    np.fill_diagonal(a, 0.0)
    want = int(round(np.trace(a @ a @ a) / 6.0))
    assert table_tricount(t, server) == want
