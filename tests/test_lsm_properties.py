"""Differential property tests for the storage engines.

Random interleavings of ingest/flush/compact/query/scan are applied to
THREE readers of the same logical table — the LSM engine's fused
single-dispatch read path, its per-run baseline path, and the legacy
single-run engine — plus a sequential dict oracle; all four must agree
for every combiner. Range scans are additionally checked against id-list
point expansion of the same range (the pre-scan read path). Runs under
real hypothesis when installed, else the deterministic shim
(tests/_hypothesis_compat.py). ``FUZZ_BUDGET`` (env, CI's weekly deep
lane) adds that many extra examples per property.

Also home to the fused read paths' structural guarantees: the
one-dispatch assertions for point queries AND range scans (memtable + L0
runs + leveled runs answered by exactly one compiled-function invocation,
every other entry point poisoned) and the batched Pallas rank kernel's
equivalence to its reference.
"""
import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.db.kvstore import COMBINERS, ShardedTable, shard_of
from repro.db.lsm import engine as lsm_engine
from repro.obs import default_registry
from repro.kernels.common import I32_MAX
from repro.kernels.sorted_search import (sorted_search_batched,
                                         sorted_search_batched_ref)

# weekly CI deep lane: FUZZ_BUDGET=N adds N examples to every property
FUZZ_BUDGET = int(os.environ.get("FUZZ_BUDGET", "0"))

# one tiny fixed geometry for EVERY example: jit caches stay warm across
# examples, so each draw costs milliseconds, not a recompile
CFG = dict(num_shards=2, capacity_per_shard=2048, batch_cap=256,
           id_capacity=1 << 8, memtable_cap=32, l0_slots=3)


def _mk(engine, fused):
    return ShardedTable(f"prop_{engine}_{fused}", engine=engine,
                        fused_reads=fused, combiner=_mk.combiner, **CFG)


def _oracle_apply(oracle, r, c, v, combiner):
    for a, b, x in zip(r, c, v):
        k = (int(a), int(b))
        if k in oracle:
            oracle[k] = {"last": float(x), "sum": oracle[k] + float(x),
                         "min": min(oracle[k], float(x)),
                         "max": max(oracle[k], float(x))}[combiner]
        else:
            oracle[k] = float(x)


def _as_dict(r, c, v):
    return {(int(a), int(b)): float(x) for a, b, x in zip(r, c, v)}


def _check_close(got, want, label, ctx):
    assert set(got) == set(want), (label, ctx,
                                   set(got) ^ set(want))
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4, abs=1e-5), \
            (label, ctx, k, got[k], want[k])


@settings(max_examples=10 + FUZZ_BUDGET, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(COMBINERS),
       st.lists(st.sampled_from(["ins", "ins", "ins", "flush", "compact",
                                 "query", "scan"]), min_size=4, max_size=12))
def test_engines_and_read_paths_agree(seed, combiner, ops):
    """insert/flush/compact in random order; every query op must return
    identical results from the fused LSM path, the per-run LSM path, the
    legacy engine, and the oracle — and every scan op must return the
    same range from the fused scan, id-list point expansion, the
    full-scan-filter baseline, the legacy engine, and the oracle. Ends
    with a full-scan comparison."""
    rng = np.random.default_rng(seed)
    _mk.combiner = combiner
    lsm = _mk("lsm", True)          # one LSM store, two read procedures
    single = _mk("single", False)
    oracle = {}

    def check_query():
        keys = np.asarray(sorted({k[0] for k in oracle}), np.int32)
        if len(keys) == 0:
            return
        pick = rng.choice(keys, size=min(12, len(keys)), replace=False)
        absent = rng.integers(0, CFG["id_capacity"], 3).astype(np.int32)
        q = np.unique(np.concatenate([pick, absent])).astype(np.int32)
        want = {k: v for k, v in oracle.items() if k[0] in set(q.tolist())}
        lsm.fused_reads = True
        fused = _as_dict(*lsm.query_rows(q))
        lsm.fused_reads = False
        perrun = _as_dict(*lsm.query_rows(q))
        lsm.fused_reads = True
        legacy = _as_dict(*single.query_rows(q))
        _check_close(fused, want, "fused", (seed, combiner))
        _check_close(perrun, want, "per-run", (seed, combiner))
        _check_close(legacy, want, "single-engine", (seed, combiner))

    def check_scan():
        # random [lo, hi): sometimes empty (hi == lo), sometimes past the
        # id space, and — mid-sequence — often spanning data that sits on
        # both sides of a flush/compaction boundary
        lo = int(rng.integers(0, CFG["id_capacity"]))
        hi = min(lo + int(rng.integers(0, 64)), CFG["id_capacity"] + 4)
        want = {k: v for k, v in oracle.items() if lo <= k[0] < hi}
        ctx = (seed, combiner, lo, hi)
        lsm.fused_reads = True
        fused = _as_dict(*lsm.scan_range(lo, hi))
        # id-list point expansion of the same range (the pre-scan path)
        ids = np.arange(lo, min(hi, CFG["id_capacity"]), dtype=np.int32)
        expanded = _as_dict(*lsm.query_rows(ids)) if len(ids) else {}
        lsm.fused_reads = False
        filtered = _as_dict(*lsm.scan_range(lo, hi))  # full-scan baseline
        lsm.fused_reads = True
        legacy = _as_dict(*single.scan_range(lo, hi))
        _check_close(fused, want, "fused-scan", ctx)
        _check_close(expanded, want, "point-expansion", ctx)
        _check_close(filtered, want, "scan-filter-baseline", ctx)
        _check_close(legacy, want, "single-engine-scan", ctx)

    for op in ops:
        if op == "ins":
            n = int(rng.integers(1, 28))
            r = rng.integers(0, CFG["id_capacity"], n).astype(np.int32)
            c = rng.integers(0, 4, n).astype(np.int32)
            v = (rng.integers(-4, 5, n).astype(np.float32)
                 if combiner == "sum" else
                 rng.normal(size=n).astype(np.float32))
            lsm.insert(r, c, v)
            single.insert(r, c, v)
            _oracle_apply(oracle, r, c, v, combiner)
        elif op == "flush":
            lsm.flush()
            single.flush()
        elif op == "compact":
            lsm.major_compact()
            single.flush()  # legacy engine has no compaction
        elif op == "scan":
            check_scan()
        else:
            check_query()
    check_query()
    check_scan()
    got = _as_dict(*lsm.scan())
    _check_close(got, oracle, "scan", (seed, combiner))


def _ctr(name: str, table: str) -> int:
    """Read one labeled counter straight from the obs registry — the
    ground truth the engine's ``.stats`` view is derived from."""
    series = default_registry().series(name, table=table)
    assert len(series) == 1, (name, table, series)
    return int(series[0].value)


def test_fused_point_query_is_one_dispatch(monkeypatch):
    """The acceptance bar: a point query against a shard holding a
    non-empty memtable, >=2 L0 runs, and >=2 leveled runs runs exactly ONE
    compiled-function invocation — counted via the obs registry's
    dispatch counter, with every other query entry point poisoned so a
    stray per-run launch fails loudly."""
    st_ = ShardedTable("one_dispatch", num_shards=1,
                       capacity_per_shard=4096, batch_cap=256,
                       id_capacity=1 << 10, combiner="sum",
                       memtable_cap=64, l0_slots=4, engine="lsm")
    rng = np.random.default_rng(0)
    oracle = {}

    def put(n, base):
        r = (base + rng.integers(0, 200, n)).astype(np.int32)
        c = rng.integers(0, 4, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        st_.insert(r, c, v)
        for a, b, x in zip(r, c, v):
            oracle[(int(a), int(b))] = oracle.get((int(a), int(b)), 0.0) \
                + float(x)

    # two leveled runs: a deep compaction, then a shallow one
    for _ in range(8):       # 8 x 64 fills L0 twice -> deepest level
        put(64, 0)
    st_.major_compact()
    for _ in range(2):
        put(64, 200)
    st_.major_compact()      # smaller merge -> shallower level
    levels_live = sum(1 for lv in st_._runs.levels if lv["n"][0] > 0)
    assert levels_live >= 2, [int(lv["n"][0]) for lv in st_._runs.levels]
    put(64, 400)             # L0 run 1
    st_.flush()
    put(64, 600)             # L0 run 2
    st_.flush()
    put(20, 800)             # non-empty memtable tail
    assert int(st_._runs.l0_used[0]) >= 2 and int(st_._mem_n[0]) > 0

    # poison every non-fused query entry point
    def boom(*a, **k):
        raise AssertionError("non-fused query path was dispatched")
    monkeypatch.setattr(lsm_engine, "run_query_gated", boom)
    monkeypatch.setattr(lsm_engine, "run_query_rows", boom)

    keys = np.asarray(sorted({k[0] for k in oracle}), np.int32)
    q = rng.choice(keys, 8, replace=False).astype(np.int32)
    before = _ctr("lsm_fused_dispatches", "one_dispatch")
    retries0 = _ctr("lsm_fused_widen_retries", "one_dispatch")
    qr, qc, qv = st_.query_rows(np.unique(q))
    after = _ctr("lsm_fused_dispatches", "one_dispatch")
    assert after - before == 1, (before, after)
    assert _ctr("lsm_fused_widen_retries", "one_dispatch") == retries0
    # the legacy .stats view must mirror the registry counter exactly
    assert st_.engine_stats()["fused_dispatches"] == after
    # and the answer is still exactly right
    want = {k: v for k, v in oracle.items() if k[0] in set(q.tolist())}
    got = _as_dict(qr, qc, qv)
    _check_close(got, want, "one-dispatch", ())
    # reads never flushed anything
    assert int(st_._mem_n[0]) > 0 and int(st_._runs.l0_used[0]) >= 2


def test_fused_range_scan_is_one_dispatch(monkeypatch):
    """The scan acceptance bar: a [lo, hi) range scan against a shard
    holding a non-empty memtable, >=2 L0 runs, and >=2 leveled runs runs
    exactly ONE compiled-function invocation — counted via the engine's
    scan-dispatch counter, with the point-query entry points (fused AND
    per-run) poisoned so any id-list point expansion fails loudly."""
    st_ = ShardedTable("one_scan", num_shards=1,
                       capacity_per_shard=4096, batch_cap=256,
                       id_capacity=1 << 10, combiner="sum",
                       memtable_cap=64, l0_slots=4, engine="lsm")
    rng = np.random.default_rng(1)
    oracle = {}

    def put(n, base):
        r = (base + rng.integers(0, 200, n)).astype(np.int32)
        c = rng.integers(0, 4, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        st_.insert(r, c, v)
        for a, b, x in zip(r, c, v):
            oracle[(int(a), int(b))] = oracle.get((int(a), int(b)), 0.0) \
                + float(x)

    for _ in range(8):       # deep compaction, then a shallow one
        put(64, 0)
    st_.major_compact()
    for _ in range(2):
        put(64, 200)
    st_.major_compact()
    levels_live = sum(1 for lv in st_._runs.levels if lv["n"][0] > 0)
    assert levels_live >= 2, [int(lv["n"][0]) for lv in st_._runs.levels]
    put(64, 400)             # L0 run 1
    st_.flush()
    put(64, 600)             # L0 run 2
    st_.flush()
    put(20, 800)             # non-empty memtable tail
    assert int(st_._runs.l0_used[0]) >= 2 and int(st_._mem_n[0]) > 0

    # poison EVERY point-query entry point: the scan must not expand the
    # range into point reads, fused or otherwise
    def boom(*a, **k):
        raise AssertionError("point-query path was dispatched for a scan")
    monkeypatch.setattr(lsm_engine, "run_query_gated", boom)
    monkeypatch.setattr(lsm_engine, "run_query_rows", boom)
    monkeypatch.setattr(lsm_engine.LSMRuns, "query_shard_fused", boom)
    monkeypatch.setattr(lsm_engine.LSMRuns, "query_shard", boom)

    lo, hi = 150, 700        # spans both levels, both L0 runs
    before = _ctr("lsm_scan_dispatches", "one_scan")
    retries0 = _ctr("lsm_scan_widen_retries", "one_scan")
    fused0 = _ctr("lsm_fused_dispatches", "one_scan")
    r, c, v = st_.scan_range(lo, hi, width=1024)
    after = _ctr("lsm_scan_dispatches", "one_scan")
    assert after - before == 1, (before, after)
    assert _ctr("lsm_scan_widen_retries", "one_scan") == retries0
    assert _ctr("lsm_fused_dispatches", "one_scan") == fused0
    # the legacy .stats view must mirror the registry counter exactly
    assert st_.engine_stats()["scan_dispatches"] == after
    want = {k: x for k, x in oracle.items() if lo <= k[0] < hi}
    _check_close(_as_dict(r, c, v), want, "one-dispatch-scan", (lo, hi))
    # scans never flushed anything
    assert int(st_._mem_n[0]) > 0 and int(st_._runs.l0_used[0]) >= 2
    # widen retry: a deliberately tiny window must re-dispatch ONCE wider
    # and still return the identical result
    r2, c2, v2 = st_.scan_range(lo, hi, width=16)
    assert _ctr("lsm_scan_widen_retries", "one_scan") == retries0 + 1
    _check_close(_as_dict(r2, c2, v2), want, "widen-retry-scan", (lo, hi))


def test_tiled_large_batch_matches_all_paths():
    """Batches far above ``fused_q_limit`` stay on the fused path, split
    into query tiles: exactly ceil(unique/tile) dispatches per shard
    (plus any widen retries), ``fused_tiles`` accounting for the split,
    ZERO per-run launches — and results identical to the per-run
    baseline, the legacy engine, and the oracle, with duplicate query ids
    re-expanded."""
    tile = 32
    mk = dict(num_shards=2, capacity_per_shard=4096, batch_cap=256,
              id_capacity=1 << 10, combiner="sum", memtable_cap=64)
    lsm = ShardedTable("tiled_lsm", engine="lsm", l0_slots=3,
                       fused_q_limit=tile, **mk)
    single = ShardedTable("tiled_single", engine="single", **mk)
    rng = np.random.default_rng(7)
    oracle = {}

    # level + L0 runs + memtable tail on both shards
    for i in range(6):
        r = rng.integers(0, 1 << 10, 48).astype(np.int32)
        c = rng.integers(0, 4, 48).astype(np.int32)
        v = rng.integers(-4, 5, 48).astype(np.float32)
        lsm.insert(r, c, v)
        single.insert(r, c, v)
        _oracle_apply(oracle, r, c, v, "sum")
        if i in (0, 2, 3):
            lsm.flush()
        if i == 3:
            lsm.major_compact()
    assert int(lsm._mem_n.max()) > 0  # a tail rides along in-dispatch

    keys = np.asarray(sorted({k[0] for k in oracle}), np.int32)
    absent = np.setdiff1d(np.arange(1 << 10, dtype=np.int32), keys)[:50]
    q = np.concatenate([keys, keys[: len(keys) // 2], absent])
    rng.shuffle(q)
    q = q.astype(np.int32)
    owner = shard_of(q, mk["num_shards"], mk["id_capacity"])
    exp_disp, exp_tiles = 0, 0
    for s in np.unique(owner):
        u = len(np.unique(q[owner == s]))
        t = -(-u // tile) if u > tile else 1
        exp_disp += t
        exp_tiles += t if t > 1 else 0
    assert exp_tiles >= 4, exp_tiles  # the batch genuinely tiles

    def deltas(fn):
        names = ("fused_dispatches", "fused_widen_retries", "fused_tiles",
                 "perrun_dispatches")
        b = {n: _ctr("lsm_" + n, "tiled_lsm") for n in names}
        out = fn()
        return out, {n: _ctr("lsm_" + n, "tiled_lsm") - b[n] for n in names}

    (fr, fc, fv), d = deltas(lambda: lsm.query_rows(q))
    # ceil(unique/tile) dispatches per shard; ONE extra allowed per widen
    assert d["fused_dispatches"] == exp_disp + d["fused_widen_retries"], \
        (d, exp_disp)
    assert d["fused_tiles"] == exp_tiles, (d, exp_tiles)
    assert d["perrun_dispatches"] == 0, d  # the fallback is retired

    lsm.fused_reads = False
    (pr, pc, pv), d_pr = deltas(lambda: lsm.query_rows(q))
    lsm.fused_reads = True
    assert d_pr["fused_dispatches"] == 0 and d_pr["perrun_dispatches"] > 0
    sr, sc, sv = single.query_rows(q)

    want_r, want_c, want_v = [], [], []
    by_row: dict = {}
    for (a, b), x in oracle.items():
        by_row.setdefault(a, []).append((b, x))
    for qid in q.tolist():
        for b, x in by_row.get(qid, ()):
            want_r.append(qid)
            want_c.append(b)
            want_v.append(x)

    def norm(r, c, v):
        r, c, v = (np.asarray(r, np.int64), np.asarray(c, np.int64),
                   np.asarray(v, np.float64))
        order = np.lexsort((v, c, r))
        return r[order], c[order], v[order]

    want = norm(want_r, want_c, want_v)
    for label, got in (("tiled-fused", (fr, fc, fv)),
                       ("per-run", (pr, pc, pv)),
                       ("single-engine", (sr, sc, sv))):
        gr, gc, gv = norm(*got)
        np.testing.assert_array_equal(gr, want[0], err_msg=label)
        np.testing.assert_array_equal(gc, want[1], err_msg=label)
        np.testing.assert_allclose(gv, want[2], rtol=1e-5, atol=1e-6,
                                   err_msg=label)


def test_empty_shard_fused_query_observes_latency():
    """An empty shard's early return must still observe the per-shard
    query latency histogram (pre-fix, the ``continue`` skipped it and the
    shard's p99 silently excluded its cheapest reads)."""
    st_ = ShardedTable("emptyobs", num_shards=1, capacity_per_shard=256,
                       batch_cap=64, id_capacity=1 << 8, engine="lsm")
    h = st_._h_shard_query[0]
    before = h.count
    r, _, _ = st_.query_rows(np.asarray([3, 9], np.int32))
    assert len(r) == 0
    assert st_.engine_stats()["fused_dispatches"] == 0  # no dispatch...
    assert h.count == before + 1                        # ...still timed


def test_major_compaction_only_compacts_full_shards():
    """Per-shard compaction scheduling: a hot shard filling ITS L0 must
    not drag a cold peer's L0 runs into a level merge (pre-fix, any
    shard's full L0 compacted every shard in lockstep)."""
    st_ = ShardedTable("selcomp", num_shards=2, capacity_per_shard=2048,
                       batch_cap=128, id_capacity=1 << 10, combiner="last",
                       memtable_cap=32, l0_slots=3, engine="lsm")
    # one L0 run for the cold shard (ids >= 512 live on shard 1)
    st_.insert(512 + np.arange(20, dtype=np.int32), np.zeros(20, np.int32),
               np.ones(20, np.float32))
    st_.flush()
    assert [int(x) for x in st_._runs.l0_used] == [0, 1]
    # fill the hot shard's L0 to the brim -> automatic major compaction
    for i in range(3):
        st_.insert(np.arange(24, dtype=np.int32) + 24 * i,
                   np.zeros(24, np.int32),
                   np.full(24, float(i), np.float32))
        st_.flush()
    assert st_.engine_stats()["major_compactions"] >= 1
    # hot shard compacted into a level; cold shard's L0 run UNTOUCHED
    assert int(st_._runs.l0_used[0]) == 0
    assert int(st_._runs.l0_used[1]) == 1
    assert sum(int(lv["n"][0]) for lv in st_._runs.levels) == 72
    assert sum(int(lv["n"][1]) for lv in st_._runs.levels) == 0
    # both shards still answer reads exactly
    got = _as_dict(*st_.query_rows(np.asarray([0, 30, 512, 531], np.int32)))
    assert got == {(0, 0): 0.0, (30, 0): 1.0, (512, 0): 1.0, (531, 0): 1.0}
    # an explicit full compaction still sweeps everything
    st_.major_compact()
    assert int(st_._runs.l0_used[1]) == 0
    assert sum(int(lv["n"][1]) for lv in st_._runs.levels) == 20


def test_fused_handles_empty_runs_and_absent_keys():
    """Static stacked shapes mean empty L0 slots/levels ride along as
    I32_MAX padding — they must contribute nothing, including for queries
    that match nothing anywhere."""
    st_ = ShardedTable("empt", num_shards=2, capacity_per_shard=1024,
                       batch_cap=128, id_capacity=1 << 8, combiner="last",
                       memtable_cap=32, engine="lsm")
    # memtable only (no runs at all)
    st_.insert(np.asarray([5], np.int32), np.asarray([1], np.int32),
               np.asarray([2.0], np.float32))
    r, c, v = st_.query_rows(np.asarray([5, 77], np.int32))
    assert _as_dict(r, c, v) == {(5, 1): 2.0}
    # runs only (flushed), absent keys
    st_.flush()
    r, c, v = st_.query_rows(np.asarray([5], np.int32))
    assert _as_dict(r, c, v) == {(5, 1): 2.0}
    r, c, v = st_.query_rows(np.asarray([77, 99], np.int32))
    assert len(r) == 0
    # fully empty shard: no dispatch needed, no crash
    empty = ShardedTable("empt2", num_shards=1, capacity_per_shard=1024,
                         batch_cap=128, id_capacity=1 << 8,
                         memtable_cap=32, engine="lsm")
    r, c, v = empty.query_rows(np.asarray([3], np.int32))
    assert len(r) == 0 and empty.engine_stats()["fused_dispatches"] == 0


def test_fused_duplicate_query_ids_parity():
    st_ = ShardedTable("dupf", num_shards=1, capacity_per_shard=256,
                       batch_cap=64, id_capacity=1 << 10, engine="lsm")
    st_.insert(np.asarray([7, 7], np.int32), np.asarray([1, 2], np.int32),
               np.asarray([1.0, 2.0], np.float32))
    r, c, v = st_.query_rows(np.asarray([7, 7], np.int32))
    assert len(r) == 4


@settings(max_examples=6 + FUZZ_BUDGET, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4),
       st.integers(1, 40))
def test_batched_rank_search_matches_ref(seed, n_runs, n_q):
    """The fused path's batched Pallas rank kernel == vmapped searchsorted
    for ragged stacked runs (interpret mode on CPU)."""
    rng = np.random.default_rng(seed)
    cap = 128
    tabs = np.full((n_runs, cap), I32_MAX, np.int32)
    for k in range(n_runs):
        n = int(rng.integers(0, cap + 1))
        tabs[k, :n] = np.sort(rng.integers(0, 500, n)).astype(np.int32)
    q = rng.integers(0, 500, n_q).astype(np.int32)
    for side in ("left", "right"):
        got = np.asarray(sorted_search_batched(tabs, q, side,
                                               interpret=True))
        ref = np.asarray(sorted_search_batched_ref(tabs, q, side))
        np.testing.assert_array_equal(got, ref, err_msg=f"{side}")
