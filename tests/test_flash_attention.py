"""Flash attention Pallas kernel vs jnp oracle, swept over shapes/GQA/
causality/dtypes (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref

rng = np.random.default_rng(11)


@pytest.mark.parametrize("b,sq,sk,h,kvh,hd,qb,kb", [
    (1, 128, 128, 4, 4, 32, 64, 64),
    (2, 256, 256, 8, 2, 16, 64, 128),    # GQA rep=4
    (1, 64, 512, 4, 1, 32, 64, 128),     # decode-ish, MQA
    (2, 512, 512, 6, 3, 64, 256, 256),   # odd head counts
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(b, sq, sk, h, kvh, hd, qb, kb, causal, dtype):
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), dtype)
    off = sk - sq if causal else 0
    got = flash_attention(q, k, v, causal=causal, q_offset=off, qb=qb, kb=kb)
    want = flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_blocked_attention():
    """The Pallas kernel and the model-side jnp blocked attention are the
    same math — cross-validate them."""
    from repro.models.layers import _blocked_sdpa_impl
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, qb=64, kb=64)
    b_ = _blocked_sdpa_impl(q, k, v, causal=True, qb=64, kb=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=2e-4, atol=2e-4)
