"""Integration: one real dry-run cell compiles on the production mesh and
produces coherent roofline terms (subprocess: needs 512 fake devices).

The full 40-cell x 2-mesh sweep is exercised by
``python -m repro.launch.dryrun --all --mesh both`` (EXPERIMENTS §Dry-run);
this test pins the machinery in CI at one cheap cell per mesh.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
from repro.launch.dryrun import run_cell   # sets XLA_FLAGS on import
import json

for multi in (False, True):
    rec = run_cell("smollm-135m", "decode_32k", multi, verbose=False)
    assert rec["chips"] == (512 if multi else 256)
    assert rec["flops_per_device"] > 0
    assert rec["collective_s"] >= 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["hbm_bytes_per_device"] < 16e9, "decode must fit one v5e"
print("DRYRUN-OK")
"""


@pytest.mark.slow
def test_dryrun_cell_both_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DRYRUN-OK" in out.stdout


@pytest.mark.slow
def test_ingest_dryrun_single_pod():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.ingest", "--dryrun",
         "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ingest dry-run" in out.stdout
    assert "all-to-all" in out.stdout  # the BatchWriter routing collective
