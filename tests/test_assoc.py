"""Unit + property tests for associative arrays (paper §II semantics)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Assoc, split_str


def dense_oracle(a: Assoc):
    """dict {(row,col): val} oracle."""
    r, c, v = a.triples()
    return {(rr, cc): vv for rr, cc, vv in zip(r, c, v)}


# ------------------------------------------------------------- construction
def test_split_str():
    assert list(split_str("a,b,c,")) == ["a", "b", "c"]
    assert list(split_str("alice bob ")) == ["alice", "bob"]
    assert list(split_str("")) == []


def test_basic_numeric():
    a = Assoc("alice,bob,", "bob,carl,", [1.0, 2.0])
    assert a.shape == (2, 2)
    assert a.nnz() == 2
    assert dense_oracle(a) == {("alice", "bob"): 1.0, ("bob", "carl"): 2.0}


def test_string_values():
    a = Assoc("alice,", "bob,", "cited,")
    r, c, v = a.triples()
    assert v[0] == "cited"
    assert not a.is_numeric()
    assert a.logical().is_numeric()


def test_broadcast_scalar():
    a = Assoc("a,b,c,", "x,", 1.0)
    assert a.nnz() == 3
    assert a.shape == (3, 1)


def test_duplicate_collision_sum():
    a = Assoc("a,a,", "x,x,", [1.0, 2.0])
    assert dense_oracle(a) == {("a", "x"): 3.0}


def test_zero_dropped():
    a = Assoc("a,b,", "x,y,", [0.0, 5.0])
    assert a.nnz() == 1
    assert a.shape == (1, 1)


def test_empty():
    a = Assoc()
    assert a.nnz() == 0 and a.shape == (0, 0)
    b = a + Assoc("a,", "b,", 2.0)
    assert dense_oracle(b) == {("a", "b"): 2.0}


# ----------------------------------------------------------------- indexing
@pytest.fixture
def graph():
    rows = "alice,alice,bob,carl,carl,dan,"
    cols = "bob,carl,alice,alice,dan,alice,"
    return Assoc(rows, cols, [1, 2, 3, 4, 5, 6])


def test_single_row(graph):
    sub = graph["alice,", :]
    assert dense_oracle(sub) == {("alice", "bob"): 1.0, ("alice", "carl"): 2.0}


def test_multi_row(graph):
    sub = graph["alice,bob,", :]
    assert sub.nnz() == 3


def test_prefix(graph):
    sub = graph["ca*,", :]
    assert set(sub.row) == {"carl"}
    assert sub.nnz() == 2


def test_range(graph):
    sub = graph["alice,:,bob,", :]
    assert set(sub.row) == {"alice", "bob"}


def test_positional(graph):
    sub = graph[0:2, :]
    assert set(sub.row) == {"alice", "bob"}  # first two sorted row keys


def test_col_query(graph):
    sub = graph[:, "alice,"]
    assert set(sub.row) == {"bob", "carl", "dan"}


def test_value_filter(graph):
    sub = graph == 4.0
    assert dense_oracle(sub) == {("carl", "alice"): 4.0}
    assert (graph > 4.0).nnz() == 2


def test_missing_key(graph):
    assert graph["zed,", :].nnz() == 0


# ------------------------------------------------------------------ algebra
def test_add(graph):
    two = graph + graph
    assert dense_oracle(two) == {k: 2 * v for k, v in dense_oracle(graph).items()}


def test_sub_cancels(graph):
    z = graph - graph
    assert z.nnz() == 0


def test_and_or():
    a = Assoc("a,b,", "x,y,", [1.0, 2.0])
    b = Assoc("b,c,", "y,z,", [5.0, 7.0])
    assert dense_oracle(a & b) == {("b", "y"): 2.0}
    assert dense_oracle(a | b) == {
        ("a", "x"): 1.0, ("b", "y"): 5.0, ("c", "z"): 7.0,
    }


def test_matmul_matches_dense():
    rng = np.random.default_rng(0)
    keys = np.asarray([f"k{i}" for i in range(6)], dtype=object)
    def rand_assoc():
        n = 12
        return Assoc(keys[rng.integers(0, 6, n)], keys[rng.integers(0, 6, n)],
                     rng.integers(1, 5, n).astype(float))
    a, b = rand_assoc(), rand_assoc()
    c = a * b
    # dense oracle over the full key universe
    da = np.zeros((6, 6)); db = np.zeros((6, 6))
    for (r, cc), v in dense_oracle(a).items():
        da[int(r[1:]), int(cc[1:])] = v
    for (r, cc), v in dense_oracle(b).items():
        db[int(r[1:]), int(cc[1:])] = v
    dc = da @ db
    for (r, cc), v in dense_oracle(c).items():
        assert dc[int(r[1:]), int(cc[1:])] == pytest.approx(v)
        dc[int(r[1:]), int(cc[1:])] = 0.0
    assert np.all(dc == 0.0)  # no entries missed


def test_transpose_involution(graph):
    assert graph.T.T.same_as(graph)


def test_sum(graph):
    assert graph.sum() == 21.0
    out = graph.sum(axis=1)
    assert dense_oracle(out)[("alice", "sum")] == 3.0


def test_bfs_is_matvec(graph):
    """Paper Fig 1: neighbors of a vertex == matrix-vector multiply."""
    v0 = Assoc("seed,", "alice,", 1.0)
    nbrs = v0 * graph
    assert set(nbrs.col) == {"bob", "carl"}


# ----------------------------------------------------- property-based tests
keys_st = st.lists(st.sampled_from([f"v{i:02d}" for i in range(8)]),
                   min_size=1, max_size=12)


def build(rows, cols, vals):
    n = min(len(rows), len(cols), len(vals))
    return Assoc(np.asarray(rows[:n], object), np.asarray(cols[:n], object),
                 np.asarray(vals[:n], float))


@settings(max_examples=60, deadline=None)
@given(keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12),
       keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12))
def test_add_commutes(r1, c1, v1, r2, c2, v2):
    a, b = build(r1, c1, v1), build(r2, c2, v2)
    assert (a + b).same_as(b + a)


@settings(max_examples=60, deadline=None)
@given(keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12))
def test_transpose_involution_prop(r, c, v):
    a = build(r, c, v)
    assert a.T.T.same_as(a)


@settings(max_examples=60, deadline=None)
@given(keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12),
       keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12))
def test_and_subset_or(r1, c1, v1, r2, c2, v2):
    a, b = build(r1, c1, v1), build(r2, c2, v2)
    inter, uni = dense_oracle(a & b), dense_oracle(a | b)
    da, db = dense_oracle(a), dense_oracle(b)
    assert set(inter) == set(da) & set(db)
    assert set(uni) == set(da) | set(db)
    for k, v in inter.items():
        assert v == min(da[k], db[k])
    for k, v in uni.items():
        assert v == max(da.get(k, -1e18), db.get(k, -1e18))


@settings(max_examples=40, deadline=None)
@given(keys_st, keys_st, st.lists(st.integers(1, 9), min_size=1, max_size=12))
def test_query_roundtrip(r, c, v):
    """Row query returns exactly the oracle's entries for that row."""
    a = build(r, c, v)
    oracle = dense_oracle(a)
    for row in a.row:
        sub = a[row + ",", :]
        assert dense_oracle(sub) == {k: w for k, w in oracle.items() if k[0] == row}
