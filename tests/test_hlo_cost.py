"""Loop-aware HLO cost model: validated against hand-computable workloads
(XLA:CPU's own cost_analysis counts while bodies once — the reason this
module exists; see launch/hlo_cost.py)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.hlo_cost import HloCostModel
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh_auto

# 1) scan of 10 dots == exactly 10 dots of flops
a = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
def g(x):
    def body(c, _):
        return (c @ c) * 0.999, None
    y, _ = jax.lax.scan(body, x, None, length=10)
    return y
c = HloCostModel(jax.jit(g).lower(a).compile().as_text()).cost()
want = 10 * 2 * 512 ** 3
assert abs(c.flops - want) / want < 0.01, (c.flops, want)

# 2) grad of scan of 10 dots == 30 dots (1 fwd + 2 bwd per layer)
def g2(x):
    def loss(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y.astype(jnp.float32) ** 2)
    return jax.value_and_grad(loss)(x)
c2 = HloCostModel(jax.jit(g2).lower(a).compile().as_text()).cost()
assert abs(c2.flops - 3 * want) / (3 * want) < 0.01, c2.flops

# 3) sharded matmul: per-device flops + all-reduce detected with ring cost
mesh = make_mesh_auto((4, 2), ("data", "model"), devices=jax.devices())
w1 = jax.ShapeDtypeStruct((256, 512), jnp.float32)
w2 = jax.ShapeDtypeStruct((512, 256), jnp.float32)
x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
sh = lambda s: NamedSharding(mesh, s)
f = jax.jit(lambda x, a, b: (x @ a) @ b,
            in_shardings=(sh(P("data", None)), sh(P(None, "model")),
                          sh(P("model", None))))
c3 = HloCostModel(f.lower(x, w1, w2).compile().as_text()).cost()
exp = 2 * (2 * 64 * 256 * 512) / 8
assert abs(c3.flops - exp) / exp < 0.01, (c3.flops, exp)
assert c3.coll_counts.get("all-reduce", 0) >= 1
# all-reduce payload: per-device [16, 256] f32 over model=2 ring
s_bytes = 16 * 256 * 4
want_link = 2.0 * s_bytes * (2 - 1) / 2
assert abs(c3.link_bytes - want_link) / want_link < 0.01, c3.link_bytes
print("HLO-COST-OK")
"""


def test_hlo_cost_model_validates():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "HLO-COST-OK" in out.stdout
