"""Hypothesis shim: real hypothesis when installed, otherwise a tiny
deterministic sampler so the property tests still exercise their invariants
(fixed seed, same @given/@settings surface) instead of failing collection.
Covers exactly the strategy surface these tests use: integers, sampled_from,
lists."""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            return _Strategy(lambda rng: xs[int(rng.integers(0, len(xs)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", 20)
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def run():  # zero-arg so pytest sees no fixture params
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*[s.draw(rng) for s in strats])
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
