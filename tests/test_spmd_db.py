"""SPMD ingest over a real (fake-device) mesh: all_to_all routing + per-shard
minor compaction must produce exactly the same table as the local driver.

Runs in a subprocess because XLA_FLAGS device-count must be set before jax
initializes (the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.db.spmd import make_spmd_ingest_step, stacked_empty
from repro.db.kvstore import ShardedTable, shard_of
from repro.kernels.common import I32_MAX

S, CAP, BCAP, IDCAP = 8, 2048, 256, 1 << 12
mesh = jax.make_mesh((S,), ("data",))
step = make_spmd_ingest_step(mesh, "data", S, IDCAP, combiner="last")

rng = np.random.default_rng(0)
tablets = stacked_empty(S, CAP)
tablets = jax.device_put(tablets, jax.tree.map(
    lambda x: NamedSharding(mesh, P("data", *([None] * (x.ndim - 1)))), tablets))

# mirror table via the local driver (oracle)
local = ShardedTable("oracle", num_shards=S, capacity_per_shard=CAP,
                     batch_cap=BCAP * S, id_capacity=IDCAP, use_pallas=False)

for it in range(3):
    # every ingestor (shard) produces its own batch, like the paper's SPMD
    br = np.full((S, BCAP), I32_MAX, np.int32)
    bc = np.full((S, BCAP), I32_MAX, np.int32)
    bv = np.zeros((S, BCAP), np.float32)
    all_r, all_c, all_v = [], [], []
    for s in range(S):
        n = int(rng.integers(50, BCAP))
        r = rng.integers(0, IDCAP, n).astype(np.int32)
        c = rng.integers(0, 100, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        br[s, :n], bc[s, :n], bv[s, :n] = r, c, v
        all_r.append(r); all_c.append(c); all_v.append(v)
    sh = NamedSharding(mesh, P("data", None))
    tablets = step(tablets,
                   jax.device_put(jnp.asarray(br), sh),
                   jax.device_put(jnp.asarray(bc), sh),
                   jax.device_put(jnp.asarray(bv), sh))
    local.insert(np.concatenate(all_r), np.concatenate(all_c),
                 np.concatenate(all_v))

got_r, got_c, got_v = [], [], []
rows = np.asarray(tablets.rows); cols = np.asarray(tablets.cols)
vals = np.asarray(tablets.vals); ns = np.asarray(tablets.n)
for s in range(S):
    got_r.append(rows[s, :ns[s]]); got_c.append(cols[s, :ns[s]])
    got_v.append(vals[s, :ns[s]])
got = (np.concatenate(got_r), np.concatenate(got_c), np.concatenate(got_v))
want = local.scan()
assert got[0].shape == want[0].shape, (got[0].shape, want[0].shape)
# both sides sorted per shard in the same shard order -> directly comparable
np.testing.assert_array_equal(got[0], want[0])
np.testing.assert_array_equal(got[1], want[1])

# last-wins across ingestors of the *same* key cannot be order-deterministic
# between drivers; values must still match 1:1 as multisets per key
import collections
gm = collections.defaultdict(list); wm = collections.defaultdict(list)
for k, v in zip(zip(got[0], got[1]), got[2]): gm[k].append(round(float(v), 5))
for k, v in zip(zip(want[0], want[1]), want[2]): wm[k].append(round(float(v), 5))
assert set(gm) == set(wm)

# ---- cross-process metrics + retrace telemetry -------------------------
# The mesh steps above ran through _instrumented: the registry must show
# 3 spmd ingest steps and a retrace count equal to the compile-cache size
# (first call compiles, steady-state steps never re-trace).
from repro.obs import Registry, default_registry

here = default_registry()
assert sum(c.value for c in
           here.series("spmd_steps", op="spmd_ingest")) == 3
retr = sum(c.value for c in here.series("lsm_retraces", table="spmd"))
shapes = sum(g.value for g in here.series("lsm_compiled_shapes",
                                          table="spmd"))
assert retr >= 1 and retr == shapes, (retr, shapes)

# DBserver.metrics(all_processes=True): a simulated peer process snapshot
# (what an SPMD launcher dumps per process) must merge into the connector
# view — counters sum on top of this process's registry.
from repro.db import dbsetup

DB = dbsetup("meshdb", dict(num_shards=2, capacity_per_shard=1024,
                            batch_cap=256, id_capacity=1 << 10))
T = DB["mtab"]
T.put_triple(np.asarray(["a", "b", "c"], object),
             np.asarray(["x", "x", "y"], object),
             np.asarray([1.0, 2.0, 3.0]))
local_only = DB.metrics()["tables"]["mtab"]["shards"]
local_sum = sum(s["ingest_entries"] for s in local_only.values())
assert local_sum == 3, local_sum

peer = Registry()
peer.counter("db_ingest_entries", table="mtab", shard=0).inc(123)
peer.counter("spmd_steps", op="spmd_ingest").inc(7)
DB.attach_process_snapshot(peer.snapshot())
merged = DB.metrics(all_processes=True)["tables"]["mtab"]["shards"]
merged_sum = sum(s["ingest_entries"] for s in merged.values())
assert merged_sum == local_sum + 123, (merged_sum, local_sum)
# single-process view stays unchanged after the merge (merge is a view,
# not a mutation of the live registry)
again = DB.metrics()["tables"]["mtab"]["shards"]
assert sum(s["ingest_entries"] for s in again.values()) == local_sum
print("SPMD-METRICS-OK")
print("SPMD-OK", len(got[0]))
"""


@pytest.mark.slow
def test_spmd_ingest_matches_local_driver():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD-OK" in out.stdout
    assert "SPMD-METRICS-OK" in out.stdout


PAIR_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.db.spmd import (l0_stacked_empty, make_spmd_lsm_pair_ingest_step,
                           make_spmd_lsm_scan_step, stacked_empty)
from repro.db.kvstore import ShardedTable
from repro.kernels.common import I32_MAX

S, BCAP, IDCAP, SLOTS = 8, 128, 1 << 12, 4
RUN_CAP = BCAP * S
mesh = jax.make_mesh((S,), ("data",))
step = make_spmd_lsm_pair_ingest_step(mesh, "data", S, IDCAP, combiner="sum")

def shard_spec(x):
    return NamedSharding(mesh, P("data", *([None] * (x.ndim - 1))))

l0 = l0_stacked_empty(S, SLOTS, RUN_CAP)
l0t = l0_stacked_empty(S, SLOTS, RUN_CAP)
l0 = jax.device_put(l0, jax.tree.map(shard_spec, l0))
l0t = jax.device_put(l0t, jax.tree.map(shard_spec, l0t))

# mirror pair via the local engine (oracle): sum combiner makes the
# cross-ingestor merge order irrelevant
local = ShardedTable("oracle", num_shards=S, capacity_per_shard=RUN_CAP * 8,
                     batch_cap=BCAP * S, id_capacity=IDCAP, combiner="sum",
                     transpose=True)

rng = np.random.default_rng(0)
sh = NamedSharding(mesh, P("data", None))
for it in range(3):
    br = np.full((S, BCAP), I32_MAX, np.int32)
    bc = np.full((S, BCAP), I32_MAX, np.int32)
    bv = np.zeros((S, BCAP), np.float32)
    all_r, all_c, all_v = [], [], []
    for s in range(S):
        n = int(rng.integers(40, BCAP))
        r = rng.integers(0, IDCAP, n).astype(np.int32)
        c = rng.integers(0, IDCAP, n).astype(np.int32)
        v = rng.integers(1, 5, n).astype(np.float32)
        br[s, :n], bc[s, :n], bv[s, :n] = r, c, v
        all_r.append(r); all_c.append(c); all_v.append(v)
    l0, l0t = step(l0, l0t,
                   jax.device_put(jnp.asarray(br), sh),
                   jax.device_put(jnp.asarray(bc), sh),
                   jax.device_put(jnp.asarray(bv), sh))
    local.insert(np.concatenate(all_r), np.concatenate(all_c),
                 np.concatenate(all_v))

# column-range scan over the TRANSPOSE stacks, outputs swapped back into
# A orientation — must equal the local engine's transpose-routed read
LO, HI = 100, 900
scan = make_spmd_lsm_scan_step(mesh, "data", combiner="sum",
                               width=RUN_CAP, transpose_output=True)
level = stacked_empty(S, RUN_CAP)  # no compaction yet: empty level runs
level = jax.device_put(level, jax.tree.map(shard_spec, level))
bounds = jnp.broadcast_to(jnp.asarray([LO, HI], jnp.int32), (S, 2))
rows, cols, vals, keep, cnt = scan(l0t, level,
                                   jax.device_put(bounds, sh))
assert int(jnp.max(cnt)) <= RUN_CAP
rows, cols = np.asarray(rows), np.asarray(cols)
vals, keep = np.asarray(vals), np.asarray(keep)
got = {}
for s in range(S):
    for r, c, v in zip(rows[s][keep[s]], cols[s][keep[s]],
                       vals[s][keep[s]]):
        got[(int(r), int(c))] = got.get((int(r), int(c)), 0.0) + float(v)

lr, lc, lv = local.scan_col_range(LO, HI)
want = {}
for r, c, v in zip(lr, lc, lv):
    want[(int(r), int(c))] = float(v)
assert set(got) == set(want), (len(got), len(want))
for k in want:
    assert abs(got[k] - want[k]) < 1e-3, (k, got[k], want[k])
print("SPMD-PAIR-OK", len(got))
"""


@pytest.mark.slow
def test_spmd_pair_ingest_and_transpose_scan_match_local_engine():
    """Dual-ingest on the mesh + column-range scan via the transpose
    stacks (``transpose_output=True``) must agree with the local engine's
    pair store (``scan_col_range``)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", PAIR_SCRIPT], env=env,
                         cwd=".", capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD-PAIR-OK" in out.stdout
