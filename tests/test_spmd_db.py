"""SPMD ingest over a real (fake-device) mesh: all_to_all routing + per-shard
minor compaction must produce exactly the same table as the local driver.

Runs in a subprocess because XLA_FLAGS device-count must be set before jax
initializes (the main pytest process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.db.spmd import make_spmd_ingest_step, stacked_empty
from repro.db.kvstore import ShardedTable, shard_of
from repro.kernels.common import I32_MAX

S, CAP, BCAP, IDCAP = 8, 2048, 256, 1 << 12
mesh = jax.make_mesh((S,), ("data",))
step = make_spmd_ingest_step(mesh, "data", S, IDCAP, combiner="last")

rng = np.random.default_rng(0)
tablets = stacked_empty(S, CAP)
tablets = jax.device_put(tablets, jax.tree.map(
    lambda x: NamedSharding(mesh, P("data", *([None] * (x.ndim - 1)))), tablets))

# mirror table via the local driver (oracle)
local = ShardedTable("oracle", num_shards=S, capacity_per_shard=CAP,
                     batch_cap=BCAP * S, id_capacity=IDCAP, use_pallas=False)

for it in range(3):
    # every ingestor (shard) produces its own batch, like the paper's SPMD
    br = np.full((S, BCAP), I32_MAX, np.int32)
    bc = np.full((S, BCAP), I32_MAX, np.int32)
    bv = np.zeros((S, BCAP), np.float32)
    all_r, all_c, all_v = [], [], []
    for s in range(S):
        n = int(rng.integers(50, BCAP))
        r = rng.integers(0, IDCAP, n).astype(np.int32)
        c = rng.integers(0, 100, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        br[s, :n], bc[s, :n], bv[s, :n] = r, c, v
        all_r.append(r); all_c.append(c); all_v.append(v)
    sh = NamedSharding(mesh, P("data", None))
    tablets = step(tablets,
                   jax.device_put(jnp.asarray(br), sh),
                   jax.device_put(jnp.asarray(bc), sh),
                   jax.device_put(jnp.asarray(bv), sh))
    local.insert(np.concatenate(all_r), np.concatenate(all_c),
                 np.concatenate(all_v))

got_r, got_c, got_v = [], [], []
rows = np.asarray(tablets.rows); cols = np.asarray(tablets.cols)
vals = np.asarray(tablets.vals); ns = np.asarray(tablets.n)
for s in range(S):
    got_r.append(rows[s, :ns[s]]); got_c.append(cols[s, :ns[s]])
    got_v.append(vals[s, :ns[s]])
got = (np.concatenate(got_r), np.concatenate(got_c), np.concatenate(got_v))
want = local.scan()
assert got[0].shape == want[0].shape, (got[0].shape, want[0].shape)
# both sides sorted per shard in the same shard order -> directly comparable
np.testing.assert_array_equal(got[0], want[0])
np.testing.assert_array_equal(got[1], want[1])

# last-wins across ingestors of the *same* key cannot be order-deterministic
# between drivers; values must still match 1:1 as multisets per key
import collections
gm = collections.defaultdict(list); wm = collections.defaultdict(list)
for k, v in zip(zip(got[0], got[1]), got[2]): gm[k].append(round(float(v), 5))
for k, v in zip(zip(want[0], want[1]), want[2]): wm[k].append(round(float(v), 5))
assert set(gm) == set(wm)
print("SPMD-OK", len(got[0]))
"""


@pytest.mark.slow
def test_spmd_ingest_matches_local_driver():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPMD-OK" in out.stdout
