"""Perf hillclimb driver (EXPERIMENTS §Perf).

Runs named variants of the three chosen cells through the dry-run pipeline
and logs the roofline terms per variant. Each variant encodes a hypothesis
(recorded in EXPERIMENTS.md) — this file is the measurement harness.

  PYTHONPATH=src python experiments/hillclimb.py --cell smollm
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.launch.dryrun import run_cell
from repro.train.optimizer import AdamWConfig

QOPT = AdamWConfig(quantized_state=True)

CELLS = {
    # worst useful ratio (0.09): tiny model over-sharded by 16-way TP
    "smollm": [
        ("baseline", dict(arch="smollm-135m", shape="prefill_32k",
                          multi_pod=False)),
        ("no_tp_seq_parallel", dict(arch="smollm-135m", shape="prefill_32k",
                                    multi_pod=False,
                                    rules_overrides={"tp_enabled": False,
                                                     "fsdp": None,
                                                     "seq": "model"})),
        ("no_tp_no_seq", dict(arch="smollm-135m", shape="prefill_32k",
                              multi_pod=False,
                              rules_overrides={"tp_enabled": False,
                                               "fsdp": None})),
        ("no_tp_seq_vocab_tp", dict(arch="smollm-135m", shape="prefill_32k",
                                    multi_pod=False,
                                    rules_overrides={"tp_enabled": False,
                                                     "fsdp": None,
                                                     "seq": "model",
                                                     "vocab_mode": "tp"})),
    ],
    # most collective-bound: 1T MoE, FSDP weight gathers dominate
    "kimi": [
        ("baseline", dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                          multi_pod=True)),
        ("quant_opt", dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                           multi_pod=True, opt_cfg=QOPT)),
        ("quant_opt_remat_nothing", dict(arch="kimi-k2-1t-a32b",
                                         shape="train_4k", multi_pod=True,
                                         opt_cfg=QOPT, remat="nothing")),
        ("quant_opt_mb4", dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                               multi_pod=True, opt_cfg=QOPT, microbatches=4)),
        ("ep_only_no_fsdp", dict(arch="kimi-k2-1t-a32b", shape="train_4k",
                                 multi_pod=True, opt_cfg=QOPT,
                                 rules_overrides={"fsdp": None})),
    ],
    # paper-representative (256k-vocab gather/scatter) + worst abs collective
    "commandr": [
        ("baseline", dict(arch="command-r-plus-104b", shape="train_4k",
                          multi_pod=False)),
        ("quant_opt", dict(arch="command-r-plus-104b", shape="train_4k",
                           multi_pod=False, opt_cfg=QOPT)),
        ("quant_opt_remat_nothing", dict(arch="command-r-plus-104b",
                                         shape="train_4k", multi_pod=False,
                                         opt_cfg=QOPT, remat="nothing")),
        ("quant_opt_mb4_nothing", dict(arch="command-r-plus-104b",
                                       shape="train_4k", multi_pod=False,
                                       opt_cfg=QOPT, remat="nothing",
                                       microbatches=4)),
        ("vocab_replicated", dict(arch="command-r-plus-104b", shape="train_4k",
                                  multi_pod=False, opt_cfg=QOPT,
                                  rules_overrides={"vocab_mode":
                                                   "replicated"})),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["all"], default="all")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    os.makedirs(args.out, exist_ok=True)
    for cell in cells:
        recs = []
        for name, kw in CELLS[cell]:
            print(f"\n=== {cell} :: {name} ===")
            try:
                rec = run_cell(**kw)
                rec["variant"] = name
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                rec = {"variant": name, "error": f"{type(e).__name__}: {e}"}
            recs.append(rec)
        path = os.path.join(args.out, f"{cell}.json")
        with open(path, "w") as f:
            json.dump(recs, f, indent=1)
        print("wrote", path)


if __name__ == "__main__":
    main()
