"""Production mesh construction (multi-pod dry-run deliverable).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state."""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    from ..compat import make_mesh_auto
    return make_mesh_auto(shape, axes, devices=devices[:n])


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
