"""Loop-aware HLO cost analysis.

XLA:CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE —
useless for scan-over-layers models (verified: a 10-iteration scan of a
1024³ dot reports one dot's flops). This module re-derives per-device cost
from the *partitioned, optimized* HLO text, multiplying loop bodies by their
``known_trip_count``:

  flops  — 2·prod(out)·prod(contracted) per dot (batch dims included via
           the output shape); elementwise flops ignored (dots dominate and
           elementwise cost is captured by the memory term).
  bytes  — per op: operands + outputs, where fusions count only their
           boundary (that is what fusion means), gathers/scatters count rows
           touched (not the whole table).
  colls  — ring-model link traffic per collective (see analysis.py), also
           trip-count multiplied.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16, "f32": 4, "s32": 4,
    "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(shape_text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0        # upper bound: every op hits HBM (CPU fusion)
    bytes_ideal: float = 0.0  # lower bound: perfect fusion — only dot/gather/
                              # scatter/collective/loop-carry traffic
    link_bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    coll_link: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_ideal += other.bytes_ideal * mult
        self.link_bytes += other.link_bytes * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + int(v * mult)
        for k, v in other.coll_link.items():
            self.coll_link[k] = self.coll_link.get(k, 0.0) + v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        self._cache: Dict[str, Cost] = {}

    def _split(self, txt: str) -> None:
        current = None
        for line in txt.splitlines():
            if " = " not in line:
                m = _HEADER_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    current = m.group(2)
                    self.comps[current] = []
                    if m.group(1):
                        self.entry = current
                    continue
            if line.strip() == "}":
                current = None
                continue
            if current is not None:
                self.comps[current].append(line)

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._cache:
            return self._cache[comp]
        self._cache[comp] = Cost()  # break cycles defensively
        total = Cost()
        shapes: Dict[str, str] = {}
        for line in self.comps.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, out_shape, op = m.group(1), m.group(2), m.group(3)
            shapes[name] = out_shape
            out_b = _shape_bytes(out_shape)

            if op == "while":
                trip_m = _TRIP_RE.search(line)
                trip = int(trip_m.group(1)) if trip_m else 1
                body_m = _BODY_RE.search(line)
                if body_m:
                    total.add(self.cost(body_m.group(1)), trip)
                total.bytes += out_b  # loop carry traffic once
                total.bytes_ideal += out_b
                continue
            if op == "fusion":
                calls_m = _CALLS_RE.search(line)
                if calls_m:
                    inner = self.cost(calls_m.group(1))
                    total.flops += inner.flops      # dots inside fusions
                    total.link_bytes += inner.link_bytes
                    for k, v in inner.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0) + v
                # fusion boundary bytes only
                total.bytes += out_b + self._operand_bytes(line, shapes)
                continue
            if op in ("call", "conditional", "async-start"):
                am = _APPLY_RE.search(line) or _CALLS_RE.search(line)
                if am:
                    total.add(self.cost(am.group(1)))
                total.bytes += out_b
                continue
            if op == "dot":
                ops_m = _OPERANDS_RE.search(line[m.end() - 1:])
                lhs_dims = None
                if ops_m:
                    operands = ops_m.group(1)
                    if "[" in operands:  # older XLA: inline operand shapes
                        found = _dims(operands)
                        if found:
                            lhs_dims = found[0][1]
                    else:
                        lhs_name = operands.split(",")[0].strip().lstrip("%")
                        if lhs_name in shapes:
                            found = _dims(shapes[lhs_name])
                            if found:
                                lhs_dims = found[0][1]
                contract = _LHS_CONTRACT_RE.search(line)
                c_elems = 1
                if lhs_dims is not None and contract:
                    for d in contract.group(1).split(","):
                        if d:
                            c_elems *= lhs_dims[int(d)]
                out_elems = 1
                for _, ds in _dims(out_shape):
                    for d in ds:
                        out_elems *= d
                total.flops += 2.0 * out_elems * c_elems
                op_b = out_b + self._operand_bytes(line, shapes)
                total.bytes += op_b
                total.bytes_ideal += op_b
                continue
            coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if coll and not op.endswith("-done"):
                s = out_b
                n = _group_size(line)
                if n > 1:
                    if coll == "all-reduce":
                        traffic = 2.0 * s * (n - 1) / n
                    elif coll == "all-gather":
                        traffic = s * (n - 1) / n
                    elif coll == "reduce-scatter":
                        traffic = s * (n - 1)
                    elif coll == "all-to-all":
                        traffic = s * (n - 1) / n
                    else:
                        traffic = float(s)
                    total.link_bytes += traffic
                    total.coll_counts[coll] = total.coll_counts.get(coll, 0) + 1
                    total.coll_link[coll] = total.coll_link.get(coll, 0.0) + traffic
                total.bytes += 2.0 * s
                total.bytes_ideal += 2.0 * s
                continue
            if op in ("gather", "scatter", "dynamic-slice",
                      "dynamic-update-slice"):
                total.bytes += 2.0 * out_b  # rows touched, not whole table
                total.bytes_ideal += 2.0 * out_b
                continue
            if op in ("parameter", "constant", "iota", "tuple",
                      "get-tuple-element", "bitcast", "reshape", "broadcast",
                      "copy-start", "copy-done", "after-all", "partition-id"):
                continue
            # generic elementwise / reduce / transpose / convert / select ...
            total.bytes += out_b + self._operand_bytes(line, shapes)
        self._cache[comp] = total
        return total

    def _operand_bytes(self, line: str, shapes: Dict[str, str]) -> int:
        m = _DEF_RE.match(line)
        rest = line[m.end() - 1:]
        ops_m = _OPERANDS_RE.search(rest)
        if not ops_m:
            return 0
        if "[" in ops_m.group(1):  # older XLA: inline operand shapes
            return _shape_bytes(ops_m.group(1))
        total = 0
        for tok in ops_m.group(1).split(","):
            tok = tok.strip().lstrip("%")
            if tok in shapes:
                total += _shape_bytes(shapes[tok])
        return total
