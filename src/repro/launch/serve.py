"""Serving driver: batched requests against a (reduced) LM on CPU, or the
decode-cell dry-run on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_reduced
from ..models import build, init_params
from ..serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build(cfg)
    params = init_params(model.param_specs, jax.random.key(0))
    engine = Engine(model, params, batch_slots=args.slots,
                    max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, rng.integers(4, 24))
                    .astype(np.int32), max_new=args.max_new)
            for _ in range(args.requests)]
    stats = engine.run(reqs)
    print(f"served {len(reqs)} requests, {stats['tokens_out']} tokens in "
          f"{stats['wall_s']:.2f}s -> {stats['tok_per_s']:.1f} tok/s")
    assert all(r.out is not None and len(r.out) > 0 for r in reqs)
    return stats


if __name__ == "__main__":
    main()
