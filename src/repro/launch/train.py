"""Training driver: D4M-store-backed data pipeline -> sharded train steps
with checkpoint/restart.

CPU-scale real runs (examples/train_lm.py wraps this); on a real pod the
same code path runs under the production mesh with --mesh single|multi.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_reduced
from ..data import TokenStore, synthetic_corpus
from ..models import build, init_params
from ..train import AdamWConfig, adamw_init, checkpoint
from ..train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--docs", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build(cfg)

    # ---- the paper's data plane: corpus lives in the sharded KV store ----
    store = TokenStore(num_shards=4)
    store.ingest(synthetic_corpus(args.docs, args.seq * 4, cfg.vocab - 1))

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    params = init_params(model.param_specs, jax.random.key(0))
    opt = adamw_init(params, opt_cfg)
    start = 0
    if args.resume and args.ckpt_dir:
        try:
            state, manifest = checkpoint.restore(
                args.ckpt_dir, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = manifest["step"]
            print(f"resumed from step {start}")
        except FileNotFoundError:
            pass

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches))
    rng = np.random.default_rng(0)
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        toks = store.sample_batch(args.batch, args.seq, rng)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["img_embeds"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.n_img_tokens, cfg.d_model))
                * 0.02, cfg.dtype)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.n_frames, cfg.d_model))
                * 0.02, cfg.dtype)
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tput = args.batch * args.seq * (step - start + 1) / max(dt, 1e-9)
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({tput:,.0f} tok/s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt})
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt})
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
