"""Roofline-term derivation from compiled dry-run artifacts (DESIGN §6).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute    = HLO flops (per device)  / 197e12
  memory     = HLO bytes (per device)  / 819e9
  collective = per-device link traffic / 50e9, traffic per op from ring
               costs applied to the partitioned-HLO operand shapes:
                 all-reduce       2·S·(n-1)/n     (S = per-device payload)
                 all-gather       S_full·(n-1)/n
                 reduce-scatter   S_full·(n-1)/n
                 all-to-all       S·(n-1)/n
                 collective-permute  S
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]     # per-device link traffic
    payload_by_kind: Dict[str, float]   # raw payload bytes
    link_bytes_total: float


def parse_collectives(hlo: str) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    link: Dict[str, float] = defaultdict(float)
    payload: Dict[str, float] = defaultdict(float)
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        s = _shape_bytes(m.group("shapes"))  # output shape(s), per device
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-reduce":
            traffic = 2.0 * s * (n - 1) / n
        elif op == "all-gather":
            traffic = s * (n - 1) / n            # output is the full gather
        elif op == "reduce-scatter":
            traffic = s * (n - 1)                # output is one shard
        elif op == "all-to-all":
            traffic = s * (n - 1) / n
        else:  # collective-permute
            traffic = float(s)
        counts[op] += 1
        link[op] += traffic
        payload[op] += float(s)
    return CollectiveStats(dict(counts), dict(link), dict(payload),
                           sum(link.values()))


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collectives: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float                  # analytic 6·N·D (or 2·N·D fwd-only)
    useful_ratio: float                 # model_flops / (hlo flops × chips)
    per_device_hbm_bytes: float         # args+temps from memory_analysis
    bytes_lower: float = 0.0            # perfect-fusion bound
    bytes_upper: float = 0.0            # every-op-hits-HBM bound

    def table_row(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "hbm_gb": self.per_device_hbm_bytes / 1e9,
            "collective_counts": self.collectives.counts,
        }


def analyze(compiled, n_chips: int, model_flops: float) -> Roofline:
    """Loop-aware cost from hlo_cost (XLA's cost_analysis counts while
    bodies once — see hlo_cost.py); memory_analysis for peak HBM."""
    from .hlo_cost import HloCostModel
    model = HloCostModel(compiled.as_text())
    cost = model.cost()
    flops = cost.flops
    # memory term: geometric mean of the perfect-fusion lower bound and the
    # every-op-hits-HBM upper bound (CPU fusion granularity != TPU; the true
    # value lives between — both bounds are recorded per cell)
    byts = (cost.bytes_ideal * cost.bytes) ** 0.5
    colls = CollectiveStats(dict(cost.coll_counts), dict(cost.coll_link),
                            {}, cost.link_bytes)
    ma = compiled.memory_analysis()
    hbm = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = colls.link_bytes_total / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_chips, 1.0)
    roof = Roofline(flops, byts, colls, compute_s, memory_s, coll_s,
                     bottleneck, model_flops, useful, hbm)
    roof.bytes_lower = cost.bytes_ideal
    roof.bytes_upper = cost.bytes
    return roof


def model_flops_for(cfg, shape_kind: str, seq: int, gb: int) -> float:
    """6·N·D for training, 2·N·D for forward-only steps (N excludes the
    embedding table; MoE uses active params)."""
    n = cfg.n_params_active() - cfg.vocab_padded * cfg.d_model
    if shape_kind == "train":
        return 6.0 * n * seq * gb
    if shape_kind == "prefill":
        return 2.0 * n * seq * gb
    return 2.0 * n * gb  # decode: one token per sequence
