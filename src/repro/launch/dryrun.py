import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, and
derive roofline terms. THE deliverable proving the distribution config is
coherent (DESIGN §6, EXPERIMENTS §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import SHAPES, all_cells, get_config
from ..models import build, make_sharder, sds_tree, sharding_tree
from ..models.spec import ShardingRules
from ..train.optimizer import AdamWConfig, adamw_update, opt_state_specs
from . import analysis
from .mesh import batch_axes, make_production_mesh


def rules_for(multi_pod: bool, overrides: dict | None = None) -> ShardingRules:
    base = dict(batch=batch_axes(multi_pod), model="model", fsdp="data",
                seq=None, kv_seq="model", expert="model")
    base.update(overrides or {})
    return ShardingRules(**base)


def build_step(model, mesh, rules, shape_kind, seq, gb, remat="dots_no_batch",
               opt_cfg: AdamWConfig | None = None, microbatches: int = 1):
    """Returns (jitted_fn, example_args as SDS, in_shardings)."""
    import jax.numpy as jnp
    cfg = model.cfg
    sh = make_sharder(rules, mesh)
    opt_cfg = opt_cfg or AdamWConfig()

    if shape_kind == "train":
        in_specs = model.train_input_specs(gb, seq)
        ospecs = opt_state_specs(model.param_specs, opt_cfg)

        def step(params, opt_state, batch):
            if microbatches == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: model.train_loss(p, batch, sh, remat))(params)
            else:
                mb = jax.tree.map(
                    lambda x: x.reshape((microbatches,
                                         x.shape[0] // microbatches)
                                        + x.shape[1:]), batch)

                def body(acc, m):
                    l, g = jax.value_and_grad(
                        lambda p: model.train_loss(p, m, sh, remat))(params)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32)
                        / microbatches, acc, g), l

                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(body, acc0, mb)
                loss = jnp.mean(losses)
            new_p, new_o = adamw_update(grads, opt_state, params, opt_cfg)
            return new_p, new_o, loss

        args = (sds_tree(model.param_specs), sds_tree(ospecs),
                sds_tree(in_specs))
        shardings = (sharding_tree(model.param_specs, rules, mesh),
                     sharding_tree(ospecs, rules, mesh),
                     sharding_tree(in_specs, rules, mesh))
        return step, args, shardings, (0, 1)

    if shape_kind == "prefill":
        in_specs = model.prefill_input_specs(gb, seq)

        def step(params, batch):
            return model.prefill(params, batch, sh)

        args = (sds_tree(model.param_specs), sds_tree(in_specs))
        shardings = (sharding_tree(model.param_specs, rules, mesh),
                     sharding_tree(in_specs, rules, mesh))
        return step, args, shardings, ()

    # decode
    in_specs = model.decode_input_specs(gb, seq)

    def step(params, batch):
        return model.decode(params, batch, sh)

    args = (sds_tree(model.param_specs), sds_tree(in_specs))
    shardings = (sharding_tree(model.param_specs, rules, mesh),
                 sharding_tree(in_specs, rules, mesh))
    return step, args, shardings, (1,)


def run_cell(arch: str, shape: str, multi_pod: bool, remat: str = "dots_no_batch",
             rules_overrides: dict | None = None, verbose: bool = True,
             opt_cfg: AdamWConfig | None = None, microbatches: int = 1):
    cfg = get_config(arch)
    model = build(cfg)
    seq, gb, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = rules_for(multi_pod, rules_overrides)
    step, args, shardings, donate = build_step(model, mesh, rules, kind,
                                               seq, gb, remat, opt_cfg,
                                               microbatches)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    mflops = analysis.model_flops_for(cfg, kind, seq, gb)
    roof = analysis.analyze(compiled, n_chips, mflops)
    rec = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
        "compile_s": round(compile_s, 1),
        "flops_per_device": roof.flops_per_device,
        "bytes_per_device": roof.bytes_per_device,
        "bytes_lower": roof.bytes_lower, "bytes_upper": roof.bytes_upper,
        "link_bytes_per_device": roof.collectives.link_bytes_total,
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s, "bottleneck": roof.bottleneck,
        "model_flops": mflops, "useful_ratio": roof.useful_ratio,
        "hbm_bytes_per_device": roof.per_device_hbm_bytes,
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "collective_counts": roof.collectives.counts,
        "collective_link_bytes": roof.collectives.bytes_by_kind,
        "remat": remat, "rules": dataclasses.asdict(rules),
        "microbatches": microbatches,
        "quantized_opt": bool(opt_cfg and opt_cfg.quantized_state),
    }
    if verbose:
        print(f"[{arch} × {shape} × {rec['mesh']}] kind={kind} "
              f"compile={compile_s:.1f}s bottleneck={roof.bottleneck}")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temps={mem.temp_size_in_bytes/1e9:.2f}GB per device")
        print(f"  cost_analysis: {roof.flops_per_device/1e9:.1f} GFLOP, "
              f"{roof.bytes_per_device/1e9:.2f} GB accessed per device")
        print(f"  terms: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"useful={roof.useful_ratio:.2f} "
              f"colls={roof.collectives.counts}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="dots_no_batch")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s, sk) for a, s, sk in all_cells()]
    else:
        cells = [(args.arch, args.shape, None)]

    records = []
    for arch, shape, skip in cells:
        for mp in meshes:
            if skip:
                records.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "skipped": skip})
                print(f"[{arch} × {shape}] SKIP: {skip}")
                continue
            try:
                records.append(run_cell(arch, shape, mp, remat=args.remat))
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                traceback.print_exc()
                records.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "error": f"{type(e).__name__}: {e}"})
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = "all" if args.all else f"{args.arch}_{args.shape}"
        path = os.path.join(args.out, f"dryrun_{tag}_{args.mesh}.json")
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print("wrote", path)
    n_err = sum(1 for r in records if "error" in r)
    print(f"cells: {len(records)}, errors: {n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
