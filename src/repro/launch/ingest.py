import os
if "XLA_FLAGS" not in os.environ:  # dry-run path needs the big fake mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SPMD-ingest launcher + dry-run.

--dryrun lowers and compiles the shard_map ingest step (bucket ->
all_to_all -> minor compaction) over the 'data' axis of the production
meshes — proving the paper's distributed BatchWriter path is coherent at
pod scale, same as the model cells.

  PYTHONPATH=src python -m repro.launch.ingest --dryrun --mesh both
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..db.spmd import make_spmd_ingest_step, stacked_empty
from ..kernels.common import I32_MAX
from .mesh import make_production_mesh


def dryrun(multi_pod: bool, capacity: int = 1 << 20, batch_cap: int = 1 << 15):
    mesh = make_production_mesh(multi_pod=multi_pod)
    s = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    # ingest axis = flattened (pod, data): one ingestor per data shard
    from ..compat import make_mesh_auto
    flat = make_mesh_auto((s,), ("data",), devices=jax.devices()[:s])
    # unwrap the host-side metrics wrapper: AOT lowering wants the raw
    # jitted step (tracing through the wrapper would count trace-time)
    step = make_spmd_ingest_step(flat, "data", s, id_capacity=1 << 22)
    step = getattr(step, "__wrapped__", step)
    tablets = stacked_empty(s, capacity)
    sh2 = NamedSharding(flat, P("data", None))
    sh1 = NamedSharding(flat, P("data"))
    t_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tablets)
    b_sds = jax.ShapeDtypeStruct((s, batch_cap), jnp.int32)
    v_sds = jax.ShapeDtypeStruct((s, batch_cap), jnp.float32)
    shardings = (jax.tree.map(
        lambda x: sh2 if len(x.shape) > 1 else sh1, t_sds), sh2, sh2, sh2)
    with flat:
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=(0,)).lower(t_sds, b_sds, b_sds, v_sds)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    import re
    colls = {}
    for kind in ("all-to-all", "all-reduce", "all-gather", "collective-permute"):
        n = len(re.findall(kind + r"[\.\(]", compiled.as_text()))
        if n:
            colls[kind] = n
    tag = "2x16x16(flat 512)" if multi_pod else "16x16(flat 256)"
    print(f"[ingest dry-run × {tag}] ingestors={s} "
          f"args={ma.argument_size_in_bytes/1e9:.2f}GB "
          f"temps={ma.temp_size_in_bytes/1e9:.2f}GB colls={colls}")
    return {"mesh": tag, "ingestors": s, "colls": colls,
            "arg_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    args = ap.parse_args()
    if args.dryrun:
        recs = []
        if args.mesh in ("single", "both"):
            recs.append(dryrun(False))
        if args.mesh in ("multi", "both"):
            recs.append(dryrun(True))
        return recs
    raise SystemExit("only --dryrun is supported in this container")


if __name__ == "__main__":
    main()
