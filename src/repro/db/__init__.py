# The Accumulo-analogue database layer (DESIGN §2): mesh-sharded sorted KV
# store + the paper's Listing-1 connector API + D4M 2.0 schema.
# Storage engines: db.lsm (leveled runs, default) | legacy single-run tablet.
# See src/repro/db/README.md for the storage architecture.
from .connector import (DBserver, Table, TablePair, dbinit, dbsetup, delete,
                        put, putTriple, recover_connector)
from .schema import DegreeTable, EdgeSchema
from .naive import NaiveTable
from . import graphulo
from . import lsm

__all__ = [
    "DBserver", "Table", "TablePair", "dbinit", "dbsetup", "delete", "put",
    "putTriple", "recover_connector", "DegreeTable", "EdgeSchema",
    "NaiveTable", "graphulo", "lsm",
]
