"""SPMD ingest over a device mesh — the distributed BatchWriter.

The paper runs k SPMD ingest processes (pMatlab / DistributedArrays.SPMD)
against Accumulo tablet servers. Here both sides live on the mesh: every
shard along the ingest axis is simultaneously an ingestor (producing a local
triple batch) and a tablet server (owning a key range). One step =

  1. each shard buckets its local batch by owner (range pre-split),
  2. one `all_to_all` exchanges the buckets (BatchWriter -> tablet routing),
  3. each shard minor-compacts what it received (`tablet_insert`).

This is the piece that must *lower and compile* on the production meshes —
exercised by tests/test_spmd_db.py (8 fake devices) and launch/ingest.py
(512-device dry-run).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.common import I32_MAX
from .kvstore import Tablet, shard_of_dev, tablet_insert


def _bucket_local(br, bc, bv, num_shards: int, id_capacity: int):
    """Bucket one ingestor's batch into [S, batch_cap] send buffers."""
    bcap = br.shape[0]
    dest = jnp.where(br == I32_MAX, num_shards - 1,
                     shard_of_dev(br, num_shards, id_capacity))
    order = jnp.argsort(dest)  # stable
    dest, sr, sc, sv = dest[order], br[order], bc[order], bv[order]
    starts = jnp.searchsorted(dest, jnp.arange(num_shards, dtype=dest.dtype))
    slot = jnp.arange(bcap, dtype=jnp.int32) - starts[dest].astype(jnp.int32)
    send_r = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sr)
    send_c = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sc)
    send_v = jnp.zeros((num_shards, bcap), jnp.float32).at[dest, slot].set(sv)
    return send_r, send_c, send_v


def make_spmd_ingest_step(mesh, axis: str, num_shards: int, id_capacity: int,
                          combiner: str = "last", use_pallas: bool = False):
    """Build the jitted SPMD ingest step for ``mesh`` (S = mesh axis size)."""

    def shard_fn(tablet: Tablet, br, bc, bv):
        # local views: tablet leaves [1, cap], batch [1, bcap]
        t = jax.tree.map(lambda x: x[0], tablet)
        send = _bucket_local(br[0], bc[0], bv[0], num_shards, id_capacity)
        recv_r = jax.lax.all_to_all(send[0], axis, 0, 0)
        recv_c = jax.lax.all_to_all(send[1], axis, 0, 0)
        recv_v = jax.lax.all_to_all(send[2], axis, 0, 0)
        new = tablet_insert(t, recv_r.reshape(-1), recv_c.reshape(-1),
                            recv_v.reshape(-1), combiner=combiner,
                            use_pallas=use_pallas)
        return jax.tree.map(lambda x: x[None], new)

    spec_t = Tablet(rows=P(axis, None), cols=P(axis, None),
                    vals=P(axis, None), n=P(axis))
    fn = jax.shard_map(shard_fn, mesh=mesh,
                       in_specs=(spec_t, P(axis, None), P(axis, None),
                                 P(axis, None)),
                       out_specs=spec_t, check_vma=False)
    return jax.jit(fn)


def stacked_empty(num_shards: int, capacity: int) -> Tablet:
    from .kvstore import tablet_empty
    one = tablet_empty(capacity)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_shards,) + x.shape), one)
