"""SPMD ingest over a device mesh — the distributed BatchWriter.

The paper runs k SPMD ingest processes (pMatlab / DistributedArrays.SPMD)
against Accumulo tablet servers. Here both sides live on the mesh: every
shard along the ingest axis is simultaneously an ingestor (producing a local
triple batch) and a tablet server (owning a key range). One step =

  1. each shard buckets its local batch by owner (range pre-split),
  2. one `all_to_all` exchanges the buckets (BatchWriter -> tablet routing),
  3. each shard minor-compacts what it received (`tablet_insert`).

This is the piece that must *lower and compile* on the production meshes —
exercised by tests/test_spmd_db.py (8 fake devices) and launch/ingest.py
(512-device dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from time import perf_counter
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..kernels.common import I32_MAX
from ..obs import default_registry, merge_snapshots
from .kvstore import Tablet, shard_of_dev, tablet_insert

from ..compat import SHARD_MAP_KW as _SHARD_MAP_KW
from ..compat import shard_map as _shard_map


def _instrumented(fn, op: str):
    """Host-side step instrumentation: per-process step counters + dispatch
    wall-time histograms (JAX dispatch is async; the histogram measures
    enqueue cost, not device compute). The raw jitted fn stays reachable as
    ``step.__wrapped__`` for callers that re-jit / AOT-lower the step
    (launch/ingest.py does).

    Compile/retrace telemetry: a jitted step's compile-cache growing after
    a call means a fresh input shape signature traced — counted into
    ``lsm_retraces{table=spmd}`` so the registry can assert steady-state
    steps never recompile (same guarantee the fused read path makes)."""
    reg = default_registry()
    c_steps = reg.counter("spmd_steps", op=op)
    c_retrace = reg.counter("lsm_retraces", table="spmd", op=op)
    g_shapes = reg.gauge("lsm_compiled_shapes", table="spmd", op=op)
    h_step = reg.histogram("db_op_latency_s", table="spmd", op=op)
    cache_size = getattr(fn, "_cache_size", None)
    state = {"n": cache_size() if cache_size else 0}

    def step(*args, **kw):
        if not reg.enabled:
            return fn(*args, **kw)
        t0 = perf_counter()
        out = fn(*args, **kw)
        c_steps.inc()
        h_step.observe(perf_counter() - t0)
        if cache_size is not None:
            n = cache_size()
            if n > state["n"]:
                c_retrace.inc(n - state["n"])
                g_shapes.set(n)
                state["n"] = n
        return out

    step.__wrapped__ = fn
    step.__name__ = f"spmd_{op}_step"
    return step


def merge_process_metrics(snapshots) -> dict:
    """Merge per-process ``Registry.snapshot()`` dicts at the host (SPMD
    launchers run one registry per process): counters sum, histograms
    bucket-merge with recomputed percentiles."""
    return merge_snapshots(snapshots)


def _bucket_local(br, bc, bv, num_shards: int, id_capacity: int):
    """Bucket one ingestor's batch into [S, batch_cap] send buffers."""
    bcap = br.shape[0]
    dest = jnp.where(br == I32_MAX, num_shards - 1,
                     shard_of_dev(br, num_shards, id_capacity))
    order = jnp.argsort(dest)  # stable
    dest, sr, sc, sv = dest[order], br[order], bc[order], bv[order]
    starts = jnp.searchsorted(dest, jnp.arange(num_shards, dtype=dest.dtype))
    slot = jnp.arange(bcap, dtype=jnp.int32) - starts[dest].astype(jnp.int32)
    send_r = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sr)
    send_c = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sc)
    send_v = jnp.zeros((num_shards, bcap), jnp.float32).at[dest, slot].set(sv)
    return send_r, send_c, send_v


def make_spmd_ingest_step(mesh, axis: str, num_shards: int, id_capacity: int,
                          combiner: str = "last", use_pallas: bool = False):
    """Build the jitted SPMD ingest step for ``mesh`` (S = mesh axis size)."""

    def shard_fn(tablet: Tablet, br, bc, bv):
        # local views: tablet leaves [1, cap], batch [1, bcap]
        t = jax.tree.map(lambda x: x[0], tablet)
        send = _bucket_local(br[0], bc[0], bv[0], num_shards, id_capacity)
        recv_r = jax.lax.all_to_all(send[0], axis, 0, 0)
        recv_c = jax.lax.all_to_all(send[1], axis, 0, 0)
        recv_v = jax.lax.all_to_all(send[2], axis, 0, 0)
        new = tablet_insert(t, recv_r.reshape(-1), recv_c.reshape(-1),
                            recv_v.reshape(-1), combiner=combiner,
                            use_pallas=use_pallas)
        return jax.tree.map(lambda x: x[None], new)

    spec_t = Tablet(rows=P(axis, None), cols=P(axis, None),
                    vals=P(axis, None), n=P(axis))
    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(spec_t, P(axis, None), P(axis, None),
                              P(axis, None)),
                    out_specs=spec_t, **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_ingest")


def stacked_empty(num_shards: int, capacity: int) -> Tablet:
    from .kvstore import tablet_empty
    one = tablet_empty(capacity)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_shards,) + x.shape), one)


# --------------------------------------------------------------------------
# LSM write path on the mesh: ingest = all_to_all + L0 append (O(batch)),
# major compaction = shard-local k-way merge of the L0 stack into the level
# run. This is what makes per-step ingest cost independent of table size —
# the legacy step above re-merges the whole tablet every step.
# --------------------------------------------------------------------------
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals", "k"], meta_fields=[])
@dataclasses.dataclass
class L0Stack:
    """Per-shard stack of L0 sorted runs: [slots, run_cap] + #used runs."""
    rows: jax.Array  # int32[slots, run_cap]
    cols: jax.Array  # int32[slots, run_cap]
    vals: jax.Array  # float32[slots, run_cap]
    k: jax.Array     # int32 number of used slots


def l0_stacked_empty(num_shards: int, slots: int, run_cap: int) -> L0Stack:
    return L0Stack(
        rows=jnp.full((num_shards, slots, run_cap), I32_MAX, jnp.int32),
        cols=jnp.full((num_shards, slots, run_cap), I32_MAX, jnp.int32),
        vals=jnp.zeros((num_shards, slots, run_cap), jnp.float32),
        k=jnp.zeros((num_shards,), jnp.int32),
    )


def _l0_spec(axis: str) -> L0Stack:
    return L0Stack(rows=P(axis, None, None), cols=P(axis, None, None),
                   vals=P(axis, None, None), k=P(axis))


def make_spmd_lsm_ingest_step(mesh, axis: str, num_shards: int,
                              id_capacity: int, combiner: str = "last"):
    """LSM ingest step: route a batch, sort + dedup it, append as one L0 run.

    Per-shard cost is O(S·bcap log) regardless of how much data the table
    already holds; compaction is deferred to ``make_spmd_lsm_compact_step``.
    The caller MUST compact when ``k`` reaches ``slots`` before the next
    step: a step against a full stack is a no-op for that shard (``k``
    saturates at ``slots`` so the host check keeps firing, and the batch
    is NOT ingested — re-submit it after compacting).
    """
    from .kvstore import _dedup_combine

    def shard_fn(l0: L0Stack, br, bc, bv):
        me = jax.tree.map(lambda x: x[0], l0)
        send = _bucket_local(br[0], bc[0], bv[0], num_shards, id_capacity)
        rr = jax.lax.all_to_all(send[0], axis, 0, 0).reshape(-1)
        rc = jax.lax.all_to_all(send[1], axis, 0, 0).reshape(-1)
        rv = jax.lax.all_to_all(send[2], axis, 0, 0).reshape(-1)
        order = jnp.lexsort((rc, rr))
        sr, sc, sv = rr[order], rc[order], rv[order]
        keep, out_v = _dedup_combine(sr, sc, sv, combiner)
        cap = sr.shape[0]
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, cap)
        run_r = jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sr, mode="drop")
        run_c = jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sc, mode="drop")
        run_v = jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop")
        slots = me.rows.shape[0]
        # full stack: the .at[slots] scatter drops (out of bounds) and k
        # saturates — see the driver contract in the docstring
        new = L0Stack(rows=me.rows.at[me.k].set(run_r, mode="drop"),
                      cols=me.cols.at[me.k].set(run_c, mode="drop"),
                      vals=me.vals.at[me.k].set(run_v, mode="drop"),
                      k=jnp.minimum(me.k + 1, slots))
        return jax.tree.map(lambda x: x[None], new)

    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), P(axis, None), P(axis, None),
                              P(axis, None)),
                    out_specs=_l0_spec(axis), **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_lsm_ingest")


def _bucket_local_tablets(br, bc, bv, splits, owners, num_shards: int):
    """Tablet-map routing variant of ``_bucket_local``: the owner shard is
    ``owners[searchsorted(splits, id, 'right')]`` with ``splits``/``owners``
    as DEVICE OPERANDS (``TabletMap.device_routing`` pads them to a static
    max tablet count; padded split slots hold ``id_capacity``, which no
    valid id reaches). A split or move changes array VALUES, never shapes
    — rebalancing the mesh does not retrace the compiled ingest step."""
    bcap = br.shape[0]
    t = jnp.searchsorted(splits, br, side="right")
    dest = jnp.where(br == I32_MAX, num_shards - 1, owners[t])
    order = jnp.argsort(dest)  # stable
    dest, sr, sc, sv = dest[order], br[order], bc[order], bv[order]
    starts = jnp.searchsorted(dest, jnp.arange(num_shards, dtype=dest.dtype))
    slot = jnp.arange(bcap, dtype=jnp.int32) - starts[dest].astype(jnp.int32)
    send_r = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sr)
    send_c = jnp.full((num_shards, bcap), I32_MAX, jnp.int32).at[dest, slot].set(sc)
    send_v = jnp.zeros((num_shards, bcap), jnp.float32).at[dest, slot].set(sv)
    return send_r, send_c, send_v


def make_spmd_tablet_ingest_step(mesh, axis: str, num_shards: int,
                                 combiner: str = "last"):
    """LSM ingest step routed by a DYNAMIC tablet map instead of the
    static range hash: same shape as ``make_spmd_lsm_ingest_step``
    (bucket → all_to_all → sort/dedup → L0 append, same full-stack
    contract), but each call takes the map's current ``(splits, owners)``
    routing arrays as replicated operands. The host rebalances by calling
    ``TabletMap.device_routing(max_T)`` again and passing the new arrays
    — no recompile, because only values changed (see
    ``_bucket_local_tablets``)."""
    from .kvstore import _dedup_combine

    def shard_fn(l0: L0Stack, br, bc, bv, splits, owners):
        me = jax.tree.map(lambda x: x[0], l0)
        send = _bucket_local_tablets(br[0], bc[0], bv[0], splits, owners,
                                     num_shards)
        rr = jax.lax.all_to_all(send[0], axis, 0, 0).reshape(-1)
        rc = jax.lax.all_to_all(send[1], axis, 0, 0).reshape(-1)
        rv = jax.lax.all_to_all(send[2], axis, 0, 0).reshape(-1)
        order = jnp.lexsort((rc, rr))
        sr, sc, sv = rr[order], rc[order], rv[order]
        keep, out_v = _dedup_combine(sr, sc, sv, combiner)
        cap = sr.shape[0]
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, cap)
        run_r = jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sr, mode="drop")
        run_c = jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sc, mode="drop")
        run_v = jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop")
        slots = me.rows.shape[0]
        new = L0Stack(rows=me.rows.at[me.k].set(run_r, mode="drop"),
                      cols=me.cols.at[me.k].set(run_c, mode="drop"),
                      vals=me.vals.at[me.k].set(run_v, mode="drop"),
                      k=jnp.minimum(me.k + 1, slots))
        return jax.tree.map(lambda x: x[None], new)

    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), P(axis, None), P(axis, None),
                              P(axis, None), P(), P()),
                    out_specs=_l0_spec(axis), **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_tablet_ingest")


def make_spmd_lsm_pair_ingest_step(mesh, axis: str, num_shards: int,
                                   id_capacity: int,
                                   combiner: str = "last"):
    """Dual-ingest step for an engine-maintained transpose pair: ONE jit
    routes the batch twice — forward triples by row owner into ``A``'s L0
    stack, swapped triples by col owner into ``A^T``'s — so both sides of
    the pair advance in the same dispatch (the mesh analogue of the local
    engine's pair-tagged WAL frame: one step, both siblings, or neither).

    Same full-stack contract as ``make_spmd_lsm_ingest_step``: when either
    stack's ``k`` hits ``slots``, compact BOTH (each via
    ``make_spmd_lsm_compact_step``) and re-submit the batch.
    """
    from .kvstore import _dedup_combine

    def routed_run(br, bc, bv):
        """all_to_all by row owner, then sort+dedup into one L0 run."""
        send = _bucket_local(br, bc, bv, num_shards, id_capacity)
        rr = jax.lax.all_to_all(send[0], axis, 0, 0).reshape(-1)
        rc = jax.lax.all_to_all(send[1], axis, 0, 0).reshape(-1)
        rv = jax.lax.all_to_all(send[2], axis, 0, 0).reshape(-1)
        order = jnp.lexsort((rc, rr))
        sr, sc, sv = rr[order], rc[order], rv[order]
        keep, out_v = _dedup_combine(sr, sc, sv, combiner)
        cap = sr.shape[0]
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, cap)
        return (jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sr, mode="drop"),
                jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sc, mode="drop"),
                jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop"))

    def append(me: L0Stack, run) -> L0Stack:
        slots = me.rows.shape[0]
        return L0Stack(rows=me.rows.at[me.k].set(run[0], mode="drop"),
                       cols=me.cols.at[me.k].set(run[1], mode="drop"),
                       vals=me.vals.at[me.k].set(run[2], mode="drop"),
                       k=jnp.minimum(me.k + 1, slots))

    def shard_fn(l0: L0Stack, l0t: L0Stack, br, bc, bv):
        me = jax.tree.map(lambda x: x[0], l0)
        met = jax.tree.map(lambda x: x[0], l0t)
        # rows and cols share one id space, so the SAME shard_of routes
        # both directions; the transpose leg just swaps the key roles
        fwd = routed_run(br[0], bc[0], bv[0])
        twd = routed_run(bc[0], br[0], bv[0])
        return (jax.tree.map(lambda x: x[None], append(me, fwd)),
                jax.tree.map(lambda x: x[None], append(met, twd)))

    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), _l0_spec(axis), P(axis, None),
                              P(axis, None), P(axis, None)),
                    out_specs=(_l0_spec(axis), _l0_spec(axis)),
                    **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_lsm_pair_ingest")


def make_spmd_lsm_query_step(mesh, axis: str, combiner: str = "last",
                             max_return: int = 64, q_tile: int = None):
    """Fused point reads on the mesh: ONE shard_map'd jit searches each
    shard's level run plus its ENTIRE L0 stack and combines the candidates
    on-device — the distributed analogue of the local engine's
    ``query_shard_fused`` (no per-run dispatches, no host combine).

    Queries arrive owner-routed as ``q[S, Qb]`` (pad = -1, which matches
    no row id); each shard answers only its slice. Age order: level run
    (oldest) = 1, L0 slot k = 2 + k (slot k flushed before k + 1). Empty
    L0 slots are inert I32_MAX padding. Returns
    (cols[S, Qb, W], vals[S, Qb, W], keep[S, Qb, W]) with
    W = (slots + 1) * max_return: per query, kept entries are its combined
    (col, val) results, cols ascending.

    ``q_tile`` mirrors the local engine's query tiling: batches wider than
    it are split along the query axis into ``q_tile``-wide blocks (the
    last padded with -1), each served by the SAME compiled step (one jit
    cache entry regardless of batch width) and the per-tile outputs
    concatenated back to ``Qb``. ``None`` keeps one dispatch per batch.
    """
    from .kvstore import _dedup_combine

    def probe(rows, cols, vals, q):
        """Direct rank search of one sorted run (no fence metadata in the
        mesh-side state; the run is device-local so the full searchsorted
        is one vectorized pass)."""
        cap = rows.shape[0]
        start = jnp.searchsorted(rows, q, side="left").astype(jnp.int32)
        end = jnp.searchsorted(rows, q, side="right").astype(jnp.int32)
        idx = start[:, None] + jnp.arange(max_return, dtype=jnp.int32)
        ok = idx < end[:, None]
        idxc = jnp.clip(idx, 0, cap - 1)
        return cols[idxc], vals[idxc], ok

    def shard_fn(l0: L0Stack, level: Tablet, q):
        me = jax.tree.map(lambda x: x[0], l0)
        lv = jax.tree.map(lambda x: x[0], level)
        qq = q[0]
        n_q = qq.shape[0]
        slots = me.rows.shape[0]
        c_lv, v_lv, ok_lv = probe(lv.rows, lv.cols, lv.vals, qq)
        c_l0, v_l0, ok_l0 = jax.vmap(
            lambda r, c, v: probe(r, c, v, qq))(me.rows, me.cols, me.vals)
        seg_c = [c_lv] + [c_l0[k] for k in range(slots)]
        seg_v = [v_lv] + [v_l0[k] for k in range(slots)]
        seg_ok = [ok_lv] + [ok_l0[k] for k in range(slots)]
        seg_age = [jnp.full((n_q, max_return), a + 1, jnp.int32)
                   for a in range(slots + 1)]
        cols_all = jnp.concatenate(seg_c, axis=1)
        vals_all = jnp.concatenate(seg_v, axis=1)
        ok_all = jnp.concatenate(seg_ok, axis=1)
        age_all = jnp.concatenate(seg_age, axis=1)
        col_m = jnp.where(ok_all, cols_all, I32_MAX)
        col_s, _, val_s = jax.lax.sort(
            (col_m, age_all, vals_all), dimension=1, num_keys=2)
        keep, out_v = jax.vmap(
            lambda r, v: _dedup_combine(r, jnp.zeros_like(r), v, combiner)
        )(col_s, val_s)
        return (col_s[None], jnp.where(keep, out_v, 0.0)[None], keep[None])

    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), Tablet(rows=P(axis, None),
                                                     cols=P(axis, None),
                                                     vals=P(axis, None),
                                                     n=P(axis)),
                              P(axis, None)),
                    out_specs=(P(axis, None, None), P(axis, None, None),
                               P(axis, None, None)), **_SHARD_MAP_KW)
    base = jax.jit(fn)
    if q_tile is None:
        return _instrumented(base, "spmd_lsm_query")

    def tiled(l0, level, q):
        n_q = q.shape[1]
        if n_q <= q_tile:
            return base(l0, level, q)
        outs = []
        for t in range(0, n_q, q_tile):
            q_blk = q[:, t:t + q_tile]
            pad = q_tile - q_blk.shape[1]
            if pad:
                q_blk = jnp.pad(q_blk, ((0, 0), (0, pad)),
                                constant_values=-1)
            outs.append(base(l0, level, q_blk))
        cols = jnp.concatenate([o[0] for o in outs], axis=1)[:, :n_q]
        vals = jnp.concatenate([o[1] for o in outs], axis=1)[:, :n_q]
        keep = jnp.concatenate([o[2] for o in outs], axis=1)[:, :n_q]
        return cols, vals, keep

    tiled.__wrapped__ = base
    return _instrumented(tiled, "spmd_lsm_query")


def make_spmd_lsm_scan_step(mesh, axis: str, combiner: str = "last",
                            width: int = 128,
                            transpose_output: bool = False):
    """Fused range scans on the mesh: ONE shard_map'd jit answers a
    ``[lo, hi)`` row-range scan per shard over its level run plus its
    ENTIRE L0 stack, merged-deduped on-device — the distributed analogue
    of the local engine's ``scan_shard_fused`` (no id-list point
    expansion, no per-run dispatches, no host combine).

    Bounds arrive per shard as ``bounds[S, 2]`` (each shard answers its
    own ``[lo, hi)`` slice; a shard outside the global range passes an
    empty interval ``lo == hi``). Both endpoints rank with ``side='left'``
    (``hi`` exclusive). Age order matches the point step: level run
    (oldest) = 1, L0 slot k = 2 + k. Returns
    (rows[S, W], cols[S, W], vals[S, W], keep[S, W], cnt_max[S]) with
    W = (slots + 1) * width, kept entries sorted lex by (row, col);
    ``cnt_max`` > width means some run's slice overflowed the window —
    re-make the step wider (batch-scanner semantics).

    ``transpose_output=True`` serves COLUMN-range scans over a pair's
    transpose sibling stacks (see ``make_spmd_lsm_pair_ingest_step``):
    the scan ranks over the sibling's row axis (= ``A``'s columns) and
    the outputs come back swapped into ``A`` orientation — rows are the
    sibling's cols and vice versa, kept entries sorted by (col, row)."""
    from .kvstore import _dedup_combine

    def window(rows, cols, vals, lohi):
        cap = rows.shape[0]
        start = jnp.searchsorted(rows, lohi[0], side="left").astype(jnp.int32)
        end = jnp.searchsorted(rows, lohi[1], side="left").astype(jnp.int32)
        idx = start + jnp.arange(width, dtype=jnp.int32)
        idxc = jnp.clip(idx, 0, cap - 1)
        return rows[idxc], cols[idxc], vals[idxc], idx < end, end - start

    def shard_fn(l0: L0Stack, level: Tablet, bounds):
        me = jax.tree.map(lambda x: x[0], l0)
        lv = jax.tree.map(lambda x: x[0], level)
        lohi = bounds[0]
        slots = me.rows.shape[0]
        r_lv, c_lv, v_lv, ok_lv, n_lv = window(lv.rows, lv.cols, lv.vals,
                                               lohi)
        r_l0, c_l0, v_l0, ok_l0, n_l0 = jax.vmap(
            lambda r, c, v: window(r, c, v, lohi))(me.rows, me.cols, me.vals)
        rows_all = jnp.concatenate([r_lv] + [r_l0[k] for k in range(slots)])
        cols_all = jnp.concatenate([c_lv] + [c_l0[k] for k in range(slots)])
        vals_all = jnp.concatenate([v_lv] + [v_l0[k] for k in range(slots)])
        ok_all = jnp.concatenate([ok_lv] + [ok_l0[k] for k in range(slots)])
        ages = jnp.concatenate(
            [jnp.full((width,), a + 1, jnp.int32) for a in range(slots + 1)])
        row_m = jnp.where(ok_all, rows_all, I32_MAX)
        col_m = jnp.where(ok_all, cols_all, I32_MAX)
        row_s, col_s, _, val_s = jax.lax.sort(
            (row_m, col_m, ages, vals_all), dimension=0, num_keys=3)
        keep, out_v = _dedup_combine(row_s, col_s, val_s, combiner)
        cnt_max = jnp.maximum(jnp.max(n_l0), n_lv)
        if transpose_output:  # sibling rows ARE A's cols: swap back
            row_s, col_s = col_s, row_s
        return (row_s[None], col_s[None],
                jnp.where(keep, out_v, 0.0)[None], keep[None], cnt_max[None])

    spec_t = Tablet(rows=P(axis, None), cols=P(axis, None),
                    vals=P(axis, None), n=P(axis))
    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), spec_t, P(axis, None)),
                    out_specs=(P(axis, None), P(axis, None), P(axis, None),
                               P(axis, None), P(axis)), **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_lsm_scan")


def make_spmd_lsm_compact_step(mesh, axis: str, combiner: str = "last",
                               use_pallas: bool = False):
    """Major compaction on the mesh: k-way merge each shard's L0 runs with
    its level run (Tablet) into a new level run; L0 empties."""
    from ..kernels.common import INTERPRET
    from ..kernels.merge_rank import kway_merge
    from .kvstore import _dedup_combine

    def shard_fn(l0: L0Stack, level: Tablet):
        me = jax.tree.map(lambda x: x[0], l0)
        lv = jax.tree.map(lambda x: x[0], level)
        slots = me.rows.shape[0]
        runs = [(lv.rows, lv.cols, lv.vals)]  # level run = oldest
        runs += [(me.rows[i], me.cols[i], me.vals[i]) for i in range(slots)]
        mr, mc, mv = kway_merge(runs, use_pallas=use_pallas,
                                interpret=INTERPRET)
        keep, out_v = _dedup_combine(mr, mc, mv, combiner)
        cap = lv.rows.shape[0]
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, cap)  # host checks n for overflow
        new_lv = Tablet(
            rows=jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(mr, mode="drop"),
            cols=jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(mc, mode="drop"),
            vals=jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop"),
            n=keep.sum().astype(jnp.int32),
        )
        empty = L0Stack(rows=jnp.full_like(me.rows, I32_MAX),
                        cols=jnp.full_like(me.cols, I32_MAX),
                        vals=jnp.zeros_like(me.vals),
                        k=jnp.zeros_like(me.k))
        return (jax.tree.map(lambda x: x[None], empty),
                jax.tree.map(lambda x: x[None], new_lv))

    spec_t = Tablet(rows=P(axis, None), cols=P(axis, None),
                    vals=P(axis, None), n=P(axis))
    fn = _shard_map(shard_fn, mesh=mesh,
                    in_specs=(_l0_spec(axis), spec_t),
                    out_specs=(_l0_spec(axis), spec_t), **_SHARD_MAP_KW)
    return _instrumented(jax.jit(fn), "spmd_lsm_compact")
