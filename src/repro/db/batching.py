"""Ingest batching by cumulative character count (paper §V).

"Both Julia and Matlab D4M ingest in batches with approximately 500,000
characters in each batch by default, which has previously been selected to
give the best performance." — we keep the same knob and the same default, so
the paper's batch-size/graph-size crossover (scale 13-14 fits in one batch)
is reproducible in the benchmark.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

DEFAULT_CHAR_BUDGET = 500_000


def triple_chars(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray) -> np.ndarray:
    """Per-triple character cost (string lengths, as the JVM connector sees)."""
    lens = np.frompyfunc(len, 1, 1)
    n = lens(rows.astype(object)).astype(np.int64)
    n += lens(cols.astype(object)).astype(np.int64)
    if vals.dtype.kind in "OUS":
        n += lens(vals.astype(object)).astype(np.int64)
    else:
        n += 8  # numeric payload serialized width
    return n


def batch_slices(char_costs: np.ndarray,
                 char_budget: int = DEFAULT_CHAR_BUDGET) -> Iterator[slice]:
    """Contiguous slices whose summed char cost is ~budget each."""
    if len(char_costs) == 0:
        return
    cum = np.cumsum(char_costs)
    start = 0
    base = 0
    for i in range(len(cum)):
        if cum[i] - base > char_budget and i > start:
            yield slice(start, i)
            start = i
            base = cum[i - 1]
    yield slice(start, len(cum))


def batch_triples(rows, cols, vals, char_budget: int = DEFAULT_CHAR_BUDGET
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    costs = triple_chars(rows, cols, vals)
    for sl in batch_slices(costs, char_budget):
        yield rows[sl], cols[sl], vals[sl]
