"""Reference connector — the paper's comparison baseline.

The paper benchmarks D4M.jl against Matlab-D4M driving the same Java
connector; the performance gap comes from host-side triple handling. Our
baseline is the equivalent 'straightforward implementation': an unsorted
append log with linear-scan queries, single-stream ingest, no routing, no
sorted runs, no kernels. The optimized connector (`connector.Table`) and
this one expose the same API, so the Fig. 3 / Fig. 4 benchmarks run both.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.assoc import Assoc
from ..core.dictionary import StringDict
from . import batching


class NaiveTable:
    def __init__(self, name: str, char_budget: int = batching.DEFAULT_CHAR_BUDGET):
        self.name = name
        self.keydict = StringDict()
        self.valdict: Optional[StringDict] = None
        self.rows = np.zeros(0, np.int32)
        self.cols = np.zeros(0, np.int32)
        self.vals = np.zeros(0, np.float32)
        self.char_budget = char_budget

    def nnz(self) -> int:
        return len(self.rows)

    def put(self, a: Assoc) -> None:
        self.put_triple(*a.triples())

    def put_triple(self, rows, cols, vals) -> None:
        rows = np.asarray(rows, object)
        cols = np.asarray(cols, object)
        vals = np.asarray(vals)
        for br, bc, bv in batching.batch_triples(rows, cols, vals,
                                                 self.char_budget):
            rid = self.keydict.encode(br)
            cid = self.keydict.encode(bc)
            if bv.dtype.kind in "OUS":
                if self.valdict is None:
                    self.valdict = StringDict()
                v = self.valdict.encode(bv.astype(object)).astype(np.float32) + 1
            else:
                v = bv.astype(np.float32)
            # unsorted append (no routing, no compaction)
            self.rows = np.concatenate([self.rows, rid])
            self.cols = np.concatenate([self.cols, cid])
            self.vals = np.concatenate([self.vals, v])

    putTriple = put_triple

    def __getitem__(self, key) -> Assoc:
        rsel, csel = key
        mask = np.ones(len(self.rows), bool)
        for sel, ids in ((rsel, self.rows), (csel, self.cols)):
            if sel is None or sel == ":" or (isinstance(sel, slice)
                                             and sel == slice(None)):
                continue
            from ..core.assoc import split_str
            toks = split_str(sel) if isinstance(sel, str) else [str(t) for t in sel]
            want = [self.keydict.get(t) for t in toks]
            mask &= np.isin(ids, [w for w in want if w >= 0])  # linear scan
        r, c, v = self.rows[mask], self.cols[mask], self.vals[mask]
        if len(r) == 0:
            return Assoc()
        rows = self.keydict.decode(r)
        cols = self.keydict.decode(c)
        vals = (self.valdict.decode(v.astype(np.int64) - 1)
                if self.valdict is not None else v.astype(np.float64))
        return Assoc(rows, cols, vals)
