"""Mesh-sharded sorted key-value store — the Accumulo analogue (DESIGN §2).

Each *tablet* holds (row_id, col_id) -> value entries on one mesh shard,
range-partitioned by row id (pre-split tablets, as in the 100M-inserts/s
Accumulo+D4M setup the paper cites). Two storage engines (see
``src/repro/db/README.md``):

  * ``engine="lsm"`` (default) — leveled sorted runs (``repro.db.lsm``):
    memtable flushes are O(memtable), major compactions k-way merge runs
    with the Pallas ``merge_rank`` kernel, reads go through bloom filters
    + fence pointers without flushing, and a WAL + snapshots provide
    crash recovery.
  * ``engine="single"`` — one fixed-capacity sorted run per shard; every
    flush merge-ranks the memtable into it (Pallas ``merge_rank``).
    Queries are rank searches (Pallas ``sorted_search``) + bounded
    gathers. Kept as the A/B baseline.

Duplicate keys combine with Accumulo iterator semantics in both engines
(last-wins versioning, sum/min/max combiners — ``db.iterators``).

All device functions are jit-compatible (static capacities, explicit valid
counts, I32_MAX key padding). Two drivers exist:
  * ``ShardedTable``      — stacked [S, cap] tablets on one device; used for
                             CPU benchmarking of k-way ingest (paper Fig. 3).
  * ``repro.db.spmd``     — shard_map driver with all_to_all mutation routing
                             for real meshes (and the multi-pod dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from time import perf_counter
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.common import I32_MAX, INTERPRET
from ..obs import default_registry, default_tracer
from ..kernels.merge_rank import merge_sorted
from ..kernels.merge_rank.ref import merge_sorted_ref
from ..kernels.sorted_search import sorted_search
from ..kernels.segment_reduce import segment_sum

COMBINERS = ("last", "sum", "min", "max")


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    """Engine/topology configuration for one store.

    Built ONCE (``db.connector.dbsetup``) and passed by reference down
    the DBserver → Table → ShardedTable chain instead of the old
    per-layer kwargs relay; round-trips through the snapshot manifest
    (``lsm.manifest``) so recovery rebuilds stores from the same record
    without re-listing fields by hand. Per-table knobs that genuinely
    vary per table (combiner, bloom sizing, wal_dir) stay constructor
    arguments.

    ``transpose=True`` makes the store maintain its transpose ``A^T`` as
    an engine-level sibling shard set (``ShardedTable.t_store``): every
    ingest batch lands in both through ONE pair-tagged WAL record, and
    column selectors become fence-rangeable scans on the sibling.

    ``dynamic_tablets=True`` replaces the static ``shard_of`` range hash
    with a mutable ``TabletMap`` (``db.tablets``): hot row ranges split
    at fence-derived median keys and tablets migrate between shards to
    balance Zipfian load (``split_tablet`` / ``move_tablet`` /
    ``maybe_rebalance``). The map rides in the snapshot manifest
    (format 3) and splits/moves journal as WAL meta frames, so recovery
    rebuilds the exact topology. Off by default: the static path is
    byte-for-byte unchanged (WAL frames stay untagged).
    """
    num_shards: int = 4
    capacity_per_shard: int = 1 << 18
    batch_cap: int = 1 << 15
    id_capacity: int = 1 << 22
    use_pallas: bool = False
    engine: str = "lsm"
    fused_reads: bool = True
    fused_q_limit: int = 512
    l0_slots: int = 4
    fanout: int = 4
    memtable_cap: int = None
    transpose: bool = False
    dynamic_tablets: bool = False

    def replace(self, **kw) -> "StoreConfig":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_manifest(cls, cfg: dict) -> "StoreConfig":
        """Build from a manifest config dict. Tolerates the legacy
        ``mem_cap`` key and ignores per-table fields stored alongside."""
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in cfg.items() if k in known}
        if "memtable_cap" not in kw and "mem_cap" in cfg:
            kw["memtable_cap"] = cfg["mem_cap"]
        return cls(**kw)


@functools.partial(
    jax.tree_util.register_dataclass, data_fields=["rows", "cols", "vals", "n"],
    meta_fields=[],
)
@dataclasses.dataclass
class Tablet:
    rows: jax.Array  # int32[cap]; valid prefix sorted lex by (row, col); pad I32_MAX
    cols: jax.Array  # int32[cap]
    vals: jax.Array  # float32[cap]
    n: jax.Array     # int32 valid count


def tablet_empty(capacity: int) -> Tablet:
    return Tablet(
        rows=jnp.full((capacity,), I32_MAX, jnp.int32),
        cols=jnp.full((capacity,), I32_MAX, jnp.int32),
        vals=jnp.zeros((capacity,), jnp.float32),
        n=jnp.zeros((), jnp.int32),
    )


def _dedup_combine(mr, mc, mv, combiner: str):
    """Collapse adjacent duplicate keys of a merged sorted run."""
    L = mr.shape[0]
    valid = mr != I32_MAX
    new = jnp.ones((L,), bool).at[1:].set((mr[1:] != mr[:-1]) | (mc[1:] != mc[:-1]))
    if combiner == "last":
        keep = valid & jnp.concatenate([new[1:], jnp.ones((1,), bool)])
        out_v = mv
    else:
        seg = jnp.cumsum(new) - 1
        contrib = jnp.where(valid, mv, 0.0 if combiner == "sum" else jnp.nan)
        if combiner == "sum":
            agg = jnp.zeros((L,), mv.dtype).at[seg].add(contrib)
        elif combiner == "min":
            agg = jnp.full((L,), jnp.inf, mv.dtype).at[seg].min(
                jnp.where(valid, mv, jnp.inf))
        elif combiner == "max":
            agg = jnp.full((L,), -jnp.inf, mv.dtype).at[seg].max(
                jnp.where(valid, mv, -jnp.inf))
        else:
            raise ValueError(f"unknown combiner {combiner!r}")
        keep = valid & new
        out_v = agg[seg]
    return keep, out_v


@functools.partial(jax.jit, static_argnames=("combiner", "use_pallas"))
def tablet_insert(t: Tablet, br, bc, bv, combiner: str = "last",
                  use_pallas: bool = True) -> Tablet:
    """Minor compaction: merge a batch (pads = I32_MAX keys) into the run.

    Returns the new tablet; ``new.n`` may exceed capacity — the host MUST
    check for overflow (Accumulo back-pressure analogue).
    """
    order = jnp.lexsort((bc, br))
    br, bc, bv = br[order], bc[order], bv[order]
    if use_pallas:
        mr, mc, mv = merge_sorted(t.rows, t.cols, t.vals, br, bc, bv,
                                  interpret=INTERPRET)
    else:
        mr, mc, mv = merge_sorted_ref(t.rows, t.cols, t.vals, br, bc, bv)
    keep, out_v = _dedup_combine(mr, mc, mv, combiner)
    cap = t.rows.shape[0]
    pos = jnp.cumsum(keep) - 1
    idx = jnp.where(keep, pos, cap)  # dropped when not kept / overflowing
    return Tablet(
        rows=jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(mr, mode="drop"),
        cols=jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(mc, mode="drop"),
        vals=jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop"),
        n=keep.sum().astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("max_return", "use_pallas"))
def tablet_query_rows(t: Tablet, q: jax.Array, max_return: int,
                      use_pallas: bool = True):
    """Point row queries: all (col, val) for each row id in ``q``.

    Returns (cols[Q, max_return], vals[Q, max_return], valid[Q, max_return],
    counts[Q]); counts may exceed max_return (host re-queries with a larger
    bound — Accumulo batch-scanner buffer semantics).
    """
    if use_pallas:
        start = sorted_search(t.rows, q, "left", interpret=INTERPRET)
        end = sorted_search(t.rows, q, "right", interpret=INTERPRET)
    else:
        start = jnp.searchsorted(t.rows, q, side="left").astype(jnp.int32)
        end = jnp.searchsorted(t.rows, q, side="right").astype(jnp.int32)
    cap = t.rows.shape[0]
    idx = start[:, None] + jnp.arange(max_return, dtype=jnp.int32)[None, :]
    ok = idx < end[:, None]
    idxc = jnp.clip(idx, 0, cap - 1)
    return t.cols[idxc], t.vals[idxc], ok, end - start


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def degree_update(deg: jax.Array, ids: jax.Array, weights: jax.Array,
                  use_pallas: bool = True) -> jax.Array:
    """Combiner-iterator analogue: accumulate counts into a dense degree row."""
    if use_pallas:
        return deg + segment_sum(ids, weights, n_segments=deg.shape[0],
                                 interpret=INTERPRET)
    valid = ids >= 0
    return deg.at[jnp.where(valid, ids, 0)].add(jnp.where(valid, weights, 0.0))


# --------------------------------------------------------------------------
# Range partitioning (pre-split tablets)
# --------------------------------------------------------------------------
def shard_of(ids: np.ndarray, num_shards: int, id_capacity: int) -> np.ndarray:
    """Owner shard by range partition of the id space (uniform pre-split)."""
    return np.minimum(
        (ids.astype(np.int64) * num_shards) // id_capacity, num_shards - 1
    ).astype(np.int32)


def shard_of_dev(ids: jax.Array, num_shards: int, id_capacity: int) -> jax.Array:
    """Device-side owner computation (ids * S must fit int32: S * id_capacity
    < 2**31, enforced by the connector's capacity config)."""
    return jnp.minimum((ids * num_shards) // id_capacity,
                       num_shards - 1).astype(jnp.int32)


def _memtable_append(mem_r, mem_c, mem_v, counts, br, bc, bv):
    """Append routed batches [S, bcap] into per-shard memtables [S, mcap]
    at the current write offsets; returns new buffers + counts."""
    s, mcap = mem_r.shape
    valid = br != I32_MAX
    pos_in_row = jnp.cumsum(valid, axis=1) - 1
    target = jnp.where(valid, counts[:, None] + pos_in_row, mcap)
    rows_idx = jnp.broadcast_to(jnp.arange(s)[:, None], br.shape)
    mem_r = mem_r.at[rows_idx, target].set(br, mode="drop")
    mem_c = mem_c.at[rows_idx, target].set(bc, mode="drop")
    mem_v = mem_v.at[rows_idx, target].set(bv, mode="drop")
    return mem_r, mem_c, mem_v, counts + valid.sum(axis=1).astype(counts.dtype)


def _memtable_append_flat(mem_r, mem_c, mem_v, counts, dest, slot, r, c, v):
    """Flat append: entry i of the (dest-sorted) batch lands at
    memtable[dest_i, counts[dest_i] + slot_i]. Pads carry dest == S and are
    dropped — work is O(batch), not O(shards × batch_cap)."""
    s = mem_r.shape[0]
    valid = dest < s
    dsafe = jnp.where(valid, dest, 0)
    col = jnp.where(valid, counts[dsafe] + slot, mem_r.shape[1])
    mem_r = mem_r.at[dest, col].set(r, mode="drop")
    mem_c = mem_c.at[dest, col].set(c, mode="drop")
    mem_v = mem_v.at[dest, col].set(v, mode="drop")
    add = jnp.zeros_like(counts).at[dsafe].add(valid.astype(counts.dtype))
    return mem_r, mem_c, mem_v, counts + add


_APPEND = jax.jit(_memtable_append)
_APPEND_FLAT = jax.jit(_memtable_append_flat)
_INSERT_CACHE: dict = {}


def _vmapped_insert(combiner: str, use_pallas: bool):
    """Module-level jit cache: compiled minor compactions persist across
    ShardedTable instances (benchmarks create many)."""
    key = (combiner, use_pallas)
    if key not in _INSERT_CACHE:
        _INSERT_CACHE[key] = jax.jit(
            jax.vmap(functools.partial(tablet_insert, combiner=combiner,
                                       use_pallas=use_pallas)))
    return _INSERT_CACHE[key]


class ShardedTable:
    """Stacked-tablet driver: S tablet servers' state on the local device.

    Simulates S SPMD ingestors for the paper's Fig. 3 study; the distributed
    execution path with identical per-shard code is ``repro.db.spmd``.

    Writes land in a per-shard *memtable* (unsorted fixed buffer); a minor
    compaction happens only when the memtable fills. Two storage engines sit
    under that memtable:

      * ``engine="lsm"`` (default) — leveled sorted runs (``db.lsm``):
        flush costs O(memtable), major compactions k-way merge runs via the
        Pallas merge_rank kernel, and reads serve from memtable + runs
        through bloom filters and fence pointers WITHOUT flushing.
      * ``engine="single"`` — the legacy single-sorted-run tablet: every
        flush merge-ranks the memtable into one O(capacity) run (kept for
        A/B benchmarking; reads flush owner shards first).

    With ``wal_dir`` set (LSM only), every ``insert`` batch is logged to an
    append-only WAL before it reaches the memtable, ``checkpoint()``
    snapshots the runs, and ``db.lsm.recover(dir)`` rebuilds the table
    after a crash.
    """

    def __init__(self, name: str, num_shards: int = None,
                 capacity_per_shard: int = None, batch_cap: int = None,
                 id_capacity: int = None, combiner: str = "last",
                 use_pallas: bool = None, memtable_cap: int = None,
                 engine: str = None, l0_slots: int = None, fanout: int = None,
                 wal_dir: str = None, fused_reads: bool = None,
                 fused_q_limit: int = None, bloom_bits_per_key=None,
                 bloom_hashes=None, transpose: bool = None,
                 dynamic_tablets: bool = None,
                 config: StoreConfig = None):
        # use_pallas=True runs the TPU kernels (interpret-mode on CPU — for
        # validation only; the XLA path is the CPU-performance path)
        assert combiner in COMBINERS
        # config is the canonical record (StoreConfig defaults when absent);
        # explicit kwargs override it so existing call sites keep working
        cfg = config if config is not None else StoreConfig()
        overrides = {k: v for k, v in dict(
            num_shards=num_shards, capacity_per_shard=capacity_per_shard,
            batch_cap=batch_cap, id_capacity=id_capacity,
            use_pallas=use_pallas, memtable_cap=memtable_cap, engine=engine,
            l0_slots=l0_slots, fanout=fanout, fused_reads=fused_reads,
            fused_q_limit=fused_q_limit, transpose=transpose,
            dynamic_tablets=dynamic_tablets).items()
            if v is not None}
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if cfg.engine not in ("lsm", "single"):
            raise ValueError(f"unknown engine {cfg.engine!r}")
        if cfg.transpose and cfg.engine != "lsm":
            raise ValueError("transpose pairs require engine='lsm'")
        if cfg.dynamic_tablets and cfg.engine != "lsm":
            raise ValueError("dynamic_tablets requires engine='lsm'")
        self.config = cfg
        self.name = name
        self.engine = cfg.engine
        self.S = cfg.num_shards
        self.cap = cfg.capacity_per_shard
        self.batch_cap = cfg.batch_cap
        self.id_capacity = cfg.id_capacity
        self.combiner = combiner
        self.use_pallas = cfg.use_pallas
        # fused_reads: serve LSM point queries via the fused path
        # (db.lsm.engine.query_shard_fused); fused_q_limit is the QUERY
        # TILE — batches beyond the tiny point bucket pad UP to it and
        # larger ones split into fixed-size tiles (one jit cache entry
        # serves every batch size, block bloom-gated per run), never the
        # per-run fallback. fused_reads=False keeps the per-run baseline.
        self.fused_reads = cfg.fused_reads
        self.fused_q_limit = cfg.fused_q_limit
        # resolved locals for the body below (kwargs may have been None)
        num_shards = cfg.num_shards
        capacity_per_shard = cfg.capacity_per_shard
        id_capacity = cfg.id_capacity
        engine = cfg.engine
        use_pallas = cfg.use_pallas
        l0_slots = cfg.l0_slots
        fanout = cfg.fanout
        self.mem_cap = cfg.memtable_cap or max(
            cfg.batch_cap * 4, min(cfg.capacity_per_shard, 1 << 18))
        self._closed = False
        # engine-maintained transpose sibling: rows and cols share one id
        # space (one keydict), so A^T routes through the same shard_of —
        # no second dictionary. The sibling has NO WAL of its own: the
        # primary logs each batch once, pair-tagged (see insert()).
        self.t_store = None
        if cfg.transpose:
            # the sibling keeps STATIC col routing even when the primary
            # runs dynamic tablets: the tablet map partitions the ROW id
            # space; the sibling's keys are our cols
            self.t_store = ShardedTable(
                name + "@T", combiner=combiner,
                bloom_bits_per_key=bloom_bits_per_key,
                bloom_hashes=bloom_hashes,
                config=dataclasses.replace(cfg, transpose=False,
                                           dynamic_tablets=False,
                                           memtable_cap=self.mem_cap))
        # dynamic tablets: mutable row-range → tablet → owner map replacing
        # the static shard_of hash; starts as its exact equivalent (one
        # tablet per shard, same boundaries) until the first split
        self.tablet_map = None
        self._migrating = False
        if cfg.dynamic_tablets:
            from .tablets import TabletMap
            self.tablet_map = TabletMap.uniform(cfg.num_shards,
                                                cfg.id_capacity)
        # per-batch latency histograms + per-shard op counters/histograms
        # (repro.obs; series reset here so a fresh table reads zeros)
        self._reg = default_registry()
        self._trace = default_tracer()
        self._h_ingest = self._reg.histogram("db_op_latency_s", table=name,
                                             op="ingest")
        self._h_query = self._reg.histogram("db_op_latency_s", table=name,
                                            op="query")
        self._h_scan = self._reg.histogram("db_op_latency_s", table=name,
                                           op="scan")
        # whole-table scans (the O(nnz) path selectors should AVOID —
        # the one-dispatch tests assert this stays flat on routed reads)
        self._c_full_scans = self._reg.counter("db_full_scans", table=name)
        self._c_shard_ingest = [
            self._reg.counter("db_ingest_entries", table=name, shard=s)
            for s in range(num_shards)]
        self._c_shard_query = [
            self._reg.counter("db_point_queries", table=name, shard=s)
            for s in range(num_shards)]
        self._c_shard_scan = [
            self._reg.counter("db_range_scans", table=name, shard=s)
            for s in range(num_shards)]
        self._h_shard_query = [
            self._reg.histogram("db_shard_op_latency_s", table=name,
                                shard=s, op="query")
            for s in range(num_shards)]
        self._h_shard_scan = [
            self._reg.histogram("db_shard_op_latency_s", table=name,
                                shard=s, op="scan")
            for s in range(num_shards)]
        self._c_tablet_splits = self._reg.counter("lsm_tablet_splits",
                                                  table=name)
        self._c_tablet_moves = self._reg.counter("lsm_tablet_moves",
                                                 table=name)
        self._c_tablet_merges = self._reg.counter("lsm_tablet_merges",
                                                  table=name)
        for inst in ([self._h_ingest, self._h_query, self._h_scan,
                      self._c_full_scans, self._c_tablet_splits,
                      self._c_tablet_moves, self._c_tablet_merges]
                     + self._c_shard_ingest + self._c_shard_query
                     + self._c_shard_scan + self._h_shard_query
                     + self._h_shard_scan):
            inst.reset()
        if engine == "lsm":
            from .lsm.bloom import BITS_PER_KEY, NUM_HASHES
            from .lsm.engine import LSMRuns
            self._runs = LSMRuns(
                num_shards, capacity_per_shard, self.mem_cap, combiner,
                use_pallas, l0_slots=l0_slots, fanout=fanout,
                bloom_bits_per_key=(BITS_PER_KEY if bloom_bits_per_key is None
                                    else bloom_bits_per_key),
                bloom_hashes=(NUM_HASHES if bloom_hashes is None
                              else bloom_hashes),
                id_capacity=id_capacity, name=name)
            self.tablets = None
            self._ctr_single = None
        else:
            self._runs = None
            self.tablets = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[tablet_empty(self.cap)] * num_shards)
            # same counter schema as the LSM engine (zeros where an op
            # doesn't apply) so A/B stats line up — satellite of ISSUE 6
            from .lsm.engine import STAT_KEYS
            self._ctr_single = {
                k: self._reg.counter("lsm_" + k, table=name)
                for k in STAT_KEYS}
            self._c_shard_flush_single = [
                self._reg.counter("lsm_shard_flushes", table=name, shard=s)
                for s in range(num_shards)]
            self._h_flush_single = self._reg.histogram(
                "db_op_latency_s", table=name, op="flush")
            # retrace/write-amp series parity with the LSM engine (always
            # zero here: the legacy path has no tracked fused builders)
            self._ctr_single_extra = [
                self._reg.counter("lsm_retraces", table=name, op="query"),
                self._reg.counter("lsm_retraces", table=name, op="scan"),
                self._reg.counter("lsm_flush_entries", table=name),
                self._reg.counter("lsm_compact_entries", table=name)]
            for inst in (list(self._ctr_single.values())
                         + self._c_shard_flush_single
                         + self._ctr_single_extra
                         + [self._h_flush_single]):
                inst.reset()
        self._mem_r = jnp.full((num_shards, self.mem_cap), I32_MAX, jnp.int32)
        self._mem_c = jnp.full((num_shards, self.mem_cap), I32_MAX, jnp.int32)
        self._mem_v = jnp.zeros((num_shards, self.mem_cap), jnp.float32)
        self._mem_n = np.zeros((num_shards,), np.int64)
        # host mirror of memtable appends (per shard): LSM reads serve the
        # unflushed tail without pulling device buffers. insert_routed()
        # bypasses the host, which invalidates the mirror until next flush.
        self._mem_mirror = [[] for _ in range(num_shards)]
        self._mirror_ok = True
        # (row, col)-sorted + combiner-deduped mirror per shard, computed
        # lazily for the fused read path (saves an in-dispatch sort) and
        # reused until the next insert touches the shard
        self._mem_sorted: dict = {}
        self._insert = _vmapped_insert(combiner, use_pallas)
        self._append = _APPEND
        self._append_flat = _APPEND_FLAT
        self._shard_views: dict = {}  # per-shard tablet slices (read cache)
        self._wal = None
        self._wal_dir = None
        self._wal_ckpt_offset = 0
        if wal_dir is not None:
            self.attach_wal(wal_dir)

    # ------------------------------------------------------- durability
    def attach_wal(self, wal_dir: str):
        """Open (or re-open) the write-ahead log under ``wal_dir``."""
        if self.engine != "lsm":
            raise ValueError("WAL durability requires engine='lsm'")
        import os
        from .lsm.manifest import wal_path
        from .lsm.wal import WriteAheadLog
        os.makedirs(wal_dir, exist_ok=True)
        if self._wal is not None:
            self._wal.close()
        self._wal_dir = wal_dir
        self._wal = WriteAheadLog(wal_path(wal_dir))
        # WAL backlog baseline: everything currently in the log predates
        # this process's appends, so a fresh attach owes a full replay
        self._wal_ckpt_offset = 0

    def checkpoint(self) -> str:
        """Flush the memtable, snapshot the runs, mark the WAL offset.
        Returns the manifest path; ``db.lsm.recover`` consumes it."""
        if self.engine != "lsm" or self._wal_dir is None:
            raise ValueError("checkpoint() needs engine='lsm' and a wal_dir")
        from .lsm.manifest import write_snapshot
        self.flush()
        path = write_snapshot(self, self._wal_dir)
        self._wal_ckpt_offset = self._wal.tell() if self._wal else 0
        return path

    def close(self) -> None:
        """Release buffers and refuse further use (connector delete())."""
        if self._closed:
            return
        if self._wal is not None:
            self._wal.close()
            self._wal = None
        if self.t_store is not None:
            self.t_store.close()
        self._runs = None
        self.tablets = None
        self._mem_r = self._mem_c = self._mem_v = None
        self._mem_n = np.zeros((self.S,), np.int64)
        self._shard_views.clear()
        self._closed = True

    def _check_open(self):
        if self._closed:
            raise RuntimeError(f"table {self.name!r} has been deleted")

    def warmup(self) -> None:
        """Precompile the flush/compaction graphs (no state mutation) so
        benchmark windows measure steady-state throughput, not jit time."""
        self._check_open()
        if self.engine == "lsm":
            self._runs.warmup(self._mem_r, self._mem_c, self._mem_v)
        else:
            jax.block_until_ready(self._insert(
                self.tablets, self._mem_r, self._mem_c, self._mem_v))
        if self.t_store is not None:
            self.t_store.warmup()

    def warm_reads(self) -> None:
        """Precompile the read path's static serving shapes against the
        CURRENT resident state (runs/levels/memtable geometry is baked
        into the fused query graph, so this must run at serving time, not
        ingest time). The LSM fused path has exactly two shapes — the
        point bucket and the ``fused_q_limit`` query tile — and the tile
        serves EVERY batch size, so one warm call here means no novel
        batch size ever retraces. The legacy engine has no
        batch-size-independent query shape to warm (its shape follows the
        batch; a fresh size always recompiles) — for it this warms only a
        nominal point batch. That asymmetry is the tiled-read claim.
        Queries probe spread-out absent ids: every shard dispatches, and
        ``lax.cond`` bloom gates compile both branches at trace time."""
        self._check_open()
        self.query_rows(np.zeros(1, np.int32))  # point bucket
        if self.engine == "lsm" and self.fused_reads:
            if self.tablet_map is not None:
                # skew-aware probe: a split/moved map can hand a shard a
                # NARROW slice of the id space — a uniform linspace would
                # give it <= 8 ids (point-bucket shape only) and the tile
                # would compile lazily on the first real batch. Sample
                # each shard's OWNED ranges instead, so both serving
                # shapes re-warm after every topology change.
                parts = [self.tablet_map.sample_shard_ids(s)
                         for s in range(self.S)]
                parts = [p for p in parts if len(p)]
                probe = (np.concatenate(parts) if parts
                         else np.zeros(1, np.int32))
            else:
                probe = np.linspace(0, self.id_capacity - 1,
                                    2 * self.S * 8 + 2).astype(np.int32)
            self.query_rows(np.unique(probe))   # > 8 ids/shard: the tile
        if self.t_store is not None:  # column selectors serve from A^T
            self.t_store.warm_reads()

    def engine_stats(self) -> dict:
        """Observability: flush/compaction counts and bloom skip rates.
        Both engines emit the SAME counter schema (the single-run engine
        reports zeros where an op doesn't apply) so A/B comparisons in
        BENCH_ingest.json line up."""
        if self.engine == "lsm":
            st = dict(self._runs.stats)
            st["l0_used"] = [int(x) for x in self._runs.l0_used]
            st["level_entries"] = [int(lv["n"].sum())
                                   for lv in self._runs.levels]
            return st
        st = {k: int(c.value) for k, c in self._ctr_single.items()}
        st["l0_used"] = [0] * self.S
        st["level_entries"] = []
        return st

    def refresh_health_gauges(self, bloom_probes: int = 0) -> None:
        """Recompute the derived health gauges for this table (and its
        transpose sibling): memtable occupancy per shard, WAL backlog,
        and — on the LSM engine — resident runs, compaction debt,
        read/write amplification, and (``bloom_probes > 0``) the
        observed-vs-theoretical bloom fp rate."""
        self._check_open()
        for s in range(self.S):
            self._reg.gauge("db_memtable_occupancy", table=self.name,
                            shard=s).set(int(self._mem_n[s]) / self.mem_cap)
        if self._wal is not None:
            self._wal.refresh_backlog_gauge(self._wal_ckpt_offset)
        if self.engine == "lsm":
            self._runs.refresh_health_gauges(bloom_probes=bloom_probes)
        else:
            # series parity with the LSM engine: one sorted run per shard
            # once flushed, never any compaction debt
            n_host = np.asarray(self.tablets.n)
            for s in range(self.S):
                self._reg.gauge("lsm_resident_runs", table=self.name,
                                shard=s).set(int(n_host[s] > 0))
                self._reg.gauge("lsm_compaction_debt_entries",
                                table=self.name, shard=s).set(0)
            self._reg.gauge("lsm_read_amplification",
                            table=self.name).set(0.0)
            self._reg.gauge("lsm_write_amplification",
                            table=self.name).set(0.0)
        if self.tablet_map is not None:
            self._reg.gauge("lsm_tablets", table=self.name).set(
                self.tablet_map.n)
            self._reg.gauge("lsm_tablet_balance", table=self.name).set(
                self.tablet_map.shard_balance())
        if self.t_store is not None:
            self.t_store.refresh_health_gauges(bloom_probes=bloom_probes)

    def nnz(self) -> int:
        self._check_open()
        if self.engine == "lsm":
            return sum(len(self.scan_shard(s)[0]) for s in range(self.S))
        self.flush()
        return int(self.tablets.n.sum())

    # ------------------------------------------------------------- ingest
    def route(self, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray):
        """Host-side BatchWriter routing: bucket triples by owner shard into
        fixed [S, batch_cap] buffers (pads = I32_MAX)."""
        dest = shard_of(rows, self.S, self.id_capacity)
        order = np.argsort(dest, kind="stable")
        rows, cols, vals, dest = rows[order], cols[order], vals[order], dest[order]
        counts = np.bincount(dest, minlength=self.S)
        if counts.max() > self.batch_cap:
            raise OverflowError(
                f"shard batch overflow: {counts.max()} > {self.batch_cap}")
        br = np.full((self.S, self.batch_cap), I32_MAX, np.int32)
        bc = np.full((self.S, self.batch_cap), I32_MAX, np.int32)
        bv = np.zeros((self.S, self.batch_cap), np.float32)
        ends = np.cumsum(counts)
        starts = ends - counts
        slot = np.arange(len(rows)) - starts[dest]
        br[dest, slot] = rows
        bc[dest, slot] = cols
        bv[dest, slot] = vals
        return br, bc, bv

    def insert(self, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
               _log: bool = True):
        """Host-side BatchWriter: bucket by owner + flat memtable append.
        With a WAL attached, the batch is journaled first (write-ahead);
        ``_log=False`` is for WAL replay during recovery.

        Transpose-enabled stores dual-ingest: the batch lands in the
        primary (routed by row) AND the transpose sibling (routed by
        col, rows/cols swapped) behind ONE pair-tagged WAL record — one
        fsync, and replay rebuilds both or neither (pair atomicity)."""
        self._check_open()
        rows = np.asarray(rows, np.int32)
        cols = np.asarray(cols, np.int32)
        vals = np.asarray(vals, np.float32)
        n = len(rows)
        if n == 0:
            return
        if n > self.mem_cap:
            raise OverflowError(f"batch {n} exceeds memtable {self.mem_cap}")
        t0 = perf_counter()
        with self._trace.span("ingest", table=self.name, n=n):
            if _log and self._wal is not None:
                pair = self.t_store is not None
                if self.tablet_map is None:
                    self._wal.append(rows, cols, vals, pair=pair)
                else:
                    # one TAGGED frame per tablet touched: a recovering
                    # process replays only its own tablets' suffix by
                    # skipping foreign frames. Duplicates of one
                    # (row, col) share a tablet, so per-tablet framing
                    # preserves within-key order (combiner semantics).
                    tidx = self.tablet_map.tablet_of(rows)
                    tids = self.tablet_map.tablet_ids
                    for t in np.unique(tidx):
                        sel = np.flatnonzero(tidx == t)
                        self._wal.append(rows[sel], cols[sel], vals[sel],
                                         pair=pair, tablet=int(tids[t]))
            self._insert_batch(rows, cols, vals)
            if self.t_store is not None:
                self.t_store._insert_batch(cols, rows, vals)
        self._h_ingest.observe(perf_counter() - t0)

    def _insert_batch(self, rows, cols, vals):
        n = len(rows)
        if n > self.mem_cap:
            raise OverflowError(f"batch {n} exceeds memtable {self.mem_cap}")
        if self.tablet_map is not None:
            tidx = self.tablet_map.tablet_of(rows)
            dest = self.tablet_map.owners[tidx].astype(np.int32)
            if not self._migrating:  # migration re-inserts aren't load
                self.tablet_map.record_load(tidx)
        else:
            dest = shard_of(rows, self.S, self.id_capacity)
        order = np.argsort(dest, kind="stable")
        dest, rows, cols, vals = dest[order], rows[order], cols[order], vals[order]
        counts_b = np.bincount(dest, minlength=self.S)
        if self._reg.enabled and not self._migrating:
            for s in np.nonzero(counts_b)[0]:
                self._c_shard_ingest[s].inc(int(counts_b[s]))
        if (self._mem_n + counts_b > self.mem_cap).any():
            self.flush()
        ends = np.cumsum(counts_b)
        if self.engine == "lsm" and self._mirror_ok:  # only LSM reads it
            starts_m = ends - counts_b
            for s in np.nonzero(counts_b)[0]:
                self._mem_mirror[s].append(
                    (rows[starts_m[s]:ends[s]], cols[starts_m[s]:ends[s]],
                     vals[starts_m[s]:ends[s]]))
                self._mem_sorted.pop(int(s), None)
        slot = np.arange(n, dtype=np.int32) - (ends - counts_b)[dest]
        pad = (1 << max(n - 1, 1).bit_length()) - n  # bucket jit shapes
        if pad:
            dest = np.pad(dest, (0, pad), constant_values=self.S)
            slot = np.pad(slot, (0, pad))
            rows = np.pad(rows, (0, pad), constant_values=I32_MAX)
            cols = np.pad(cols, (0, pad), constant_values=I32_MAX)
            vals = np.pad(vals, (0, pad))
        self._mem_r, self._mem_c, self._mem_v, cnt = self._append_flat(
            self._mem_r, self._mem_c, self._mem_v,
            jnp.asarray(self._mem_n, jnp.int32), jnp.asarray(dest),
            jnp.asarray(slot), jnp.asarray(rows), jnp.asarray(cols),
            jnp.asarray(vals))
        self._mem_n = np.asarray(cnt, np.int64)

    def insert_routed(self, br, bc, bv):
        """Memtable append of already-routed [S, batch_cap] buffers; minor
        compaction when a shard's memtable would overflow. (Not journaled —
        the routed path is the SPMD benchmark path, not the durable one.)"""
        self._check_open()
        if self.t_store is not None:
            raise ValueError(
                "insert_routed() does not maintain the transpose sibling; "
                "use insert() on a transpose-enabled store (or "
                "spmd.make_spmd_lsm_pair_ingest_step under shard_map)")
        incoming = np.asarray((np.asarray(br) != I32_MAX).sum(axis=1))
        if (self._mem_n + incoming > self.mem_cap).any():
            self.flush()
        self._mirror_ok = False  # device-side append: host mirror is stale
        for m in self._mem_mirror:
            m.clear()
        self._mem_r, self._mem_c, self._mem_v, counts = self._append(
            self._mem_r, self._mem_c, self._mem_v,
            jnp.asarray(self._mem_n, jnp.int32), br, bc, bv)
        self._mem_n = np.asarray(counts, np.int64)

    def flush(self) -> None:
        """Minor compaction: memtable -> L0 run (LSM, O(memtable)) or merge
        into the single sorted run (legacy, O(capacity))."""
        self._check_open()
        if self._mem_n.max(initial=0) == 0:
            return
        if self.engine == "lsm":
            self._runs.flush_memtable(self._mem_r, self._mem_c, self._mem_v)
        else:
            t0 = perf_counter()
            with self._trace.span("flush", table=self.name):
                new = self._insert(self.tablets, self._mem_r, self._mem_c,
                                   self._mem_v)
                if int(new.n.max()) > self.cap:
                    raise OverflowError(
                        f"tablet overflow in {self.name}: "
                        f"{int(new.n.max())} > {self.cap}")
                self.tablets = new
            self._shard_views.clear()
            self._h_flush_single.observe(perf_counter() - t0)
            self._ctr_single["flushes"].inc()
            for s in np.nonzero(self._mem_n)[0]:
                self._c_shard_flush_single[s].inc()
        self._mem_r = jnp.full((self.S, self.mem_cap), I32_MAX, jnp.int32)
        self._mem_c = jnp.full((self.S, self.mem_cap), I32_MAX, jnp.int32)
        self._mem_v = jnp.zeros((self.S, self.mem_cap), jnp.float32)
        self._mem_n = np.zeros((self.S,), np.int64)
        self._mem_mirror = [[] for _ in range(self.S)]
        self._mirror_ok = True
        self._mem_sorted.clear()
        if self.t_store is not None:
            self.t_store.flush()

    def _mem_host(self, s: int):
        """Host mirror of shard ``s``'s memtable, or None if stale."""
        if not self._mirror_ok:
            return None
        if not self._mem_mirror[s]:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32),
                    np.zeros(0, np.float32))
        return tuple(np.concatenate([b[i] for b in self._mem_mirror[s]])
                     for i in range(3))

    def _mem_host_sorted(self, s: int):
        """The mirror, (row, col)-sorted and pre-combined for the fused
        read path (commutes with the cross-run combine, exactly like a
        flush would); cached until the next insert touches the shard."""
        got = self._mem_sorted.get(s)
        if got is not None:
            return got
        mh = self._mem_host(s)
        if mh is None or len(mh[0]) == 0:
            return mh
        from .lsm.engine import combine_triples
        got = combine_triples(mh[0].astype(np.int32),
                              mh[1].astype(np.int32),
                              mh[2].astype(np.float32),
                              np.arange(len(mh[0]), dtype=np.int32),
                              self.combiner)
        self._mem_sorted[s] = got
        return got

    def major_compact(self) -> None:
        """Force a major compaction (LSM): flush, then merge all runs."""
        self._check_open()
        if self.engine != "lsm":
            return
        self.flush()
        self._runs.major_compact()
        if self.t_store is not None:
            self.t_store.major_compact()

    # ------------------------------------------------------------ tablets
    def _require_tablets(self):
        if self.tablet_map is None:
            raise ValueError(
                f"table {self.name!r} was not built with "
                "dynamic_tablets=True")
        return self.tablet_map

    def split_tablet(self, tablet_id: int = None, key: int = None):
        """Split one tablet's row range in two (metadata only — both
        halves stay on the owning shard until a move rebalances them).

        Defaults pick the hottest tablet by recorded load and split at
        the owner shard's fence-derived median key inside the range (the
        engine's fence pointers uniformly sample each sorted run, so the
        median fence approximates the median data key for free). The op
        is journaled as a WAL meta frame BEFORE the map changes, with the
        new tablet id pinned, so replay reproduces the identical map.
        Returns the new right-half tablet id, or None when the tablet
        cannot split (range width 1)."""
        self._check_open()
        tm = self._require_tablets()
        if tablet_id is None:
            tablet_id = int(tm.tablet_ids[int(np.argmax(tm.loads))])
        lo, hi = tm.range_of(tablet_id)
        if hi - lo <= 1:
            return None
        if key is None:
            self.flush()  # fences only see flushed data
            s = int(tm.owners[tm.index_of(tablet_id)])
            key = self._runs.fence_median(s, lo, hi)
        key = int(key)
        if not lo < key < hi:
            return None
        new_id = tm.next_id
        if self._wal is not None:
            self._wal.append_meta({"op": "split", "tablet": int(tablet_id),
                                   "key": key, "new": new_id})
        tm.split(tablet_id, key, new_id=new_id)
        self._c_tablet_splits.inc()
        return new_id

    def move_tablet(self, tablet_id: int, dst: int) -> bool:
        """Migrate one tablet to shard ``dst``: journal a WAL meta frame,
        update the map, then physically re-route the SOURCE shard (scan
        its combined triples, clear its runs, re-insert through the new
        map). Re-inserting combined values once each is a no-op under all
        four combiners, so reads are unchanged modulo placement. Returns
        False when ``dst`` already owns the tablet."""
        self._check_open()
        tm = self._require_tablets()
        dst = int(dst)
        if not 0 <= dst < self.S:
            raise ValueError(f"destination shard {dst} out of range")
        src = int(tm.owners[tm.index_of(tablet_id)])
        if src == dst:
            return False
        if self._wal is not None:
            self._wal.append_meta({"op": "move", "tablet": int(tablet_id),
                                   "to": dst})
        tm.move(tablet_id, dst)
        self._migrate_shard(src)
        self._c_tablet_moves.inc()
        return True

    def merge_tablet(self, tablet_id: int) -> bool:
        """Merge a tablet with its right neighbor (the inverse of
        ``split_tablet`` — Accumulo's range coalescing for gone-cold
        ranges). If the neighbor lives on a different shard it is first
        moved to this tablet's owner (journaled like any move); the merge
        itself is metadata only. Returns False when there is no right
        neighbor."""
        self._check_open()
        tm = self._require_tablets()
        i = tm.index_of(tablet_id)
        if i + 1 >= tm.n:
            return False
        if tm.owners[i] != tm.owners[i + 1]:
            self.move_tablet(int(tm.tablet_ids[i + 1]), int(tm.owners[i]))
        if self._wal is not None:
            self._wal.append_meta({"op": "merge", "tablet": int(tablet_id)})
        tm.merge(tablet_id)
        self._c_tablet_merges.inc()
        return True

    def _migrate_shard(self, src: int) -> None:
        """Re-route everything resident on shard ``src`` through the
        CURRENT tablet map: flush, scan the shard's combined triples,
        clear its runs, and re-insert in memtable-sized chunks. Entries
        whose tablet still lives on ``src`` land back; moved tablets'
        entries land on their new owner. Not WAL-logged (the data is
        already durable before the move's meta frame) and not counted as
        ingest (``_migrating`` guards the load/ingest counters)."""
        self.flush()
        r, c, v = self.scan_shard(src)
        self._runs.clear_shard(src)
        if len(r) == 0:
            return
        self._migrating = True
        try:
            step = self.mem_cap
            for i in range(0, len(r), step):
                self._insert_batch(r[i:i + step], c[i:i + step],
                                   v[i:i + step])
        finally:
            self._migrating = False
        self.flush()

    def maybe_rebalance(self, split_threshold: float = 1.5,
                        max_tablets: int = None, min_load: float = 1.0):
        """One round of the tablet balance policy (the Accumulo master
        analogue, driven by the obs-recorded per-tablet loads):

        1. SPLIT any tablet whose load exceeds ``split_threshold`` times
           the mean per-shard load (bounded by ``max_tablets``, default
           ``8 * S``) — a hot range becomes two movable halves;
        2. LPT-assign tablets to shards (heaviest tablet to the least
           loaded shard, current owner preferred on ties so a balanced
           map never thrashes) and migrate the changed assignments;
        3. decay the load signal by half so the policy tracks the recent
           workload.

        Returns ``{"splits", "moves", "balance"}`` where balance is the
        post-rebalance max/mean per-shard load (1.0 = perfect). Greedy
        LPT bounds it by (4/3 - 1/(3S)) whenever no single tablet
        dominates, comfortably under the ≤ 2.0 acceptance bar."""
        self._check_open()
        tm = self._require_tablets()
        out = {"splits": 0, "moves": 0}
        total = float(tm.loads.sum())
        if total >= min_load:
            cap = 8 * self.S if max_tablets is None else int(max_tablets)
            mean_shard = total / self.S
            for _ in range(self.S):  # bounded split rounds per call
                i = int(np.argmax(tm.loads))
                if (tm.loads[i] <= split_threshold * mean_shard
                        or tm.n >= cap):
                    break
                if self.split_tablet(int(tm.tablet_ids[i])) is None:
                    break
                out["splits"] += 1
            order = np.argsort(tm.loads, kind="stable")[::-1]
            shard_load = np.zeros(self.S)
            assign = np.empty(tm.n, np.int32)
            for i in order:
                d = int(np.argmin(shard_load))
                cur = int(tm.owners[i])
                if shard_load[cur] <= shard_load[d] + 1e-9:
                    d = cur  # tie: keep the tablet where it lives
                assign[i] = d
                shard_load[d] += tm.loads[i]
            for i in np.flatnonzero(assign != tm.owners):
                if self.move_tablet(int(tm.tablet_ids[i]), int(assign[i])):
                    out["moves"] += 1
        tm.decay()
        out["balance"] = tm.shard_balance()
        self._reg.gauge("lsm_tablet_balance", table=self.name).set(
            out["balance"])
        self._reg.gauge("lsm_tablets", table=self.name).set(tm.n)
        return out

    def _apply_replayed_meta(self, op: dict) -> None:
        """Apply one WAL meta frame during recovery: the map mutates at
        the SAME log point it did live — including the physical move
        migration — so data frames replayed after the op route to the
        identical shards (``lsm.manifest.recover``)."""
        if self.tablet_map is None:
            return
        tm = self.tablet_map
        kind = op.get("op")
        if kind == "split":
            tm.split(int(op["tablet"]), int(op["key"]),
                     new_id=int(op["new"]))
        elif kind == "move":
            src = int(tm.owners[tm.index_of(int(op["tablet"]))])
            dst = int(op["to"])
            if src != dst:
                tm.move(int(op["tablet"]), dst)
                self._migrate_shard(src)
        elif kind == "merge":
            tm.merge(int(op["tablet"]))

    # -------------------------------------------------------------- query
    def query_rows(self, row_ids: np.ndarray, max_return: int = 256,
                   col_filter: np.ndarray = None):
        """Point queries; returns (row_id, col_id, val) numpy triples.

        LSM engine: served from memtable + runs (bloom/fence read path) —
        point reads never trigger a flush. Legacy engine: flushes only when
        a QUERIED shard's memtable is non-empty (read-your-writes without
        the old unconditional global flush).

        ``col_filter`` restricts results to the given column id set; on
        the fused LSM path the membership test runs ON DEVICE inside the
        dispatch (no host post-filter), other paths filter on the host.
        """
        self._check_open()
        t_call = perf_counter()
        host_filter = None
        if col_filter is not None:
            col_filter = np.asarray(col_filter, np.int32)
            if not (self.engine == "lsm" and self.fused_reads):
                host_filter, col_filter = col_filter, None
        row_ids = np.asarray(row_ids, np.int32)
        if self.tablet_map is not None:
            tidx = self.tablet_map.tablet_of(row_ids)
            self.tablet_map.record_load(tidx)  # queries drive splits too
            owner = self.tablet_map.owners[tidx].astype(np.int32)
        else:
            owner = shard_of(row_ids, self.S, self.id_capacity)
        out_r, out_c, out_v = [], [], []
        if self.engine == "lsm":
            for s in np.unique(owner):
                q = row_ids[owner == s]
                self._c_shard_query[int(s)].inc(len(q))
                t_sh = perf_counter()
                # duplicate query ids return duplicate results (legacy-
                # engine parity): query unique ids, then re-expand
                uq, ucnt = np.unique(q, return_counts=True)
                mem_n = int(self._mem_n[s])
                mh = self._mem_host(int(s))
                if self.fused_reads:
                    mem_sorted = False
                    if mem_n == 0:
                        fmem = None
                    elif mh is not None:
                        fmem = self._mem_host_sorted(int(s))
                        mem_sorted = True
                    else:  # mirror stale: slice device buffers (lazy)
                        fmem = (self._mem_r[s, :mem_n],
                                self._mem_c[s, :mem_n],
                                self._mem_v[s, :mem_n])
                    if fmem is None and not self._runs.resident_runs(int(s)):
                        # empty shard: nothing to dispatch — still observed
                        self._h_shard_query[int(s)].observe(
                            perf_counter() - t_sh)
                        continue
                    r, c, v = self._runs.query_shard_fused(
                        int(s), uq, mem_host=fmem, max_return=max_return,
                        mem_sorted=mem_sorted, q_tile=self.fused_q_limit,
                        col_filter=col_filter)
                else:
                    if mh is None and mem_n:  # stale: pull device bufs
                        mem = (self._mem_r[s], self._mem_c[s],
                               self._mem_v[s])
                    else:
                        mem = (None, None, None)
                    r, c, v = self._runs.query_shard(
                        int(s), uq, *mem, mem_n, max_return, mem_host=mh)
                if len(r) and (ucnt > 1).any():
                    rep = ucnt[np.searchsorted(uq, r)]
                    r, c, v = (np.repeat(r, rep), np.repeat(c, rep),
                               np.repeat(v, rep))
                self._h_shard_query[int(s)].observe(perf_counter() - t_sh)
                out_r.append(r); out_c.append(c); out_v.append(v)
        else:
            owners = np.unique(owner)
            if self._mem_n[owners].max(initial=0) > 0:
                self.flush()
            for s in owners:
                q = row_ids[owner == s]
                self._c_shard_query[int(s)].inc(len(q))
                t_sh = perf_counter()
                t = self._shard_views.get(int(s))
                if t is None:  # slicing stacked arrays copies ~MBs; cache it
                    t = jax.tree.map(lambda x: x[s], self.tablets)
                    self._shard_views[int(s)] = t
                cols, vals, ok, cnt = tablet_query_rows(
                    t, jnp.asarray(q), max_return,
                    use_pallas=self.use_pallas)
                cnt = np.asarray(cnt)
                if cnt.max(initial=0) > max_return:  # widen (batch scanner)
                    cols, vals, ok, cnt = tablet_query_rows(
                        t, jnp.asarray(q), int(cnt.max()),
                        use_pallas=self.use_pallas)
                ok = np.asarray(ok)
                cols, vals = np.asarray(cols), np.asarray(vals)
                qi, ki = np.nonzero(ok)
                self._h_shard_query[int(s)].observe(perf_counter() - t_sh)
                out_r.append(q[qi])
                out_c.append(cols[qi, ki])
                out_v.append(vals[qi, ki])
        if len(row_ids):
            self._h_query.observe(perf_counter() - t_call)
        if not out_r:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        r = np.concatenate(out_r)
        c = np.concatenate(out_c)
        v = np.concatenate(out_v)
        if host_filter is not None:  # non-fused paths: filter on the host
            keep = np.isin(c, host_filter)
            r, c, v = r[keep], c[keep], v[keep]
        return r, c, v

    def scan_range(self, lo: int, hi: int, width: int = 64,
                   col_filter: np.ndarray = None):
        """Row-range scan: all (row, col, val) with ``lo <= row < hi``,
        sorted lex by (row, col) per shard — the server-side analogue of an
        Accumulo tablet range scan.

        LSM + ``fused_reads``: each overlapping shard is answered by ONE
        fused fence-to-fence dispatch (``scan_shard_fused``) — no id-list
        point expansion. With ``fused_reads`` off the per-shard full scan
        is filtered on the host (the A/B baseline); the legacy single-run
        engine flushes and slices its sorted run by the endpoint ranks.

        ``col_filter`` restricts results to the given column id set; the
        fused path masks on-device inside the scan dispatch, other paths
        filter on the host."""
        self._check_open()
        t_call = perf_counter()
        lo, hi = int(lo), int(hi)
        host_filter = None
        if col_filter is not None:
            col_filter = np.asarray(col_filter, np.int32)
            if not (self.engine == "lsm" and self.fused_reads):
                host_filter, col_filter = col_filter, None
        out_r, out_c, out_v = [], [], []
        if hi > lo:
            if self.tablet_map is not None:
                # per-owner sub-ranges in KEY order (adjacent same-owner
                # tablets coalesced): concatenated segment outputs stay
                # globally (row, col)-sorted even under a skewed map
                segs = self.tablet_map.segments(lo, hi)
                self.tablet_map.touch_range(lo, hi)
            else:
                s_lo = int(shard_of(np.asarray([lo]), self.S,
                                    self.id_capacity)[0])
                s_hi = int(shard_of(np.asarray([max(hi - 1, lo)]), self.S,
                                    self.id_capacity)[0])
                # each shard clips the full range itself (fence ranks)
                segs = [(s, lo, hi) for s in range(s_lo, s_hi + 1)]
            if self.engine != "lsm":
                if self._mem_n[[s for s, _, _ in segs]].max(initial=0) > 0:
                    self.flush()
            for s, seg_lo, seg_hi in segs:
                self._c_shard_scan[s].inc()
                t_sh = perf_counter()
                if self.engine == "lsm":
                    mem_n = int(self._mem_n[s])
                    mh = self._mem_host(s)
                    if self.fused_reads:
                        mem_sorted = False
                        if mem_n == 0:
                            fmem = None
                        elif mh is not None:
                            fmem = self._mem_host_sorted(int(s))
                            mem_sorted = True
                        else:  # mirror stale: slice device buffers (lazy)
                            fmem = (self._mem_r[s, :mem_n],
                                    self._mem_c[s, :mem_n],
                                    self._mem_v[s, :mem_n])
                        r, c, v = self._runs.scan_shard_fused(
                            int(s), seg_lo, seg_hi, mem_host=fmem,
                            width=width, mem_sorted=mem_sorted,
                            col_filter=col_filter)
                    else:  # baseline: full shard scan + host range filter
                        r, c, v = self.scan_shard(s)
                        keep = (r >= seg_lo) & (r < seg_hi)
                        r, c, v = r[keep], c[keep], v[keep]
                else:  # legacy single run: endpoint ranks on the host copy
                    t = self._shard_views.get(int(s))
                    if t is None:
                        t = jax.tree.map(lambda x: x[s], self.tablets)
                        self._shard_views[int(s)] = t
                    rows = np.asarray(t.rows)
                    a = int(np.searchsorted(rows, seg_lo, side="left"))
                    b = int(np.searchsorted(rows, seg_hi, side="left"))
                    r = rows[a:b]
                    c = np.asarray(t.cols)[a:b]
                    v = np.asarray(t.vals)[a:b]
                self._h_shard_scan[s].observe(perf_counter() - t_sh)
                if len(r):
                    out_r.append(r); out_c.append(c); out_v.append(v)
            self._h_scan.observe(perf_counter() - t_call)
        if not out_r:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        r = np.concatenate(out_r)
        c = np.concatenate(out_c)
        v = np.concatenate(out_v)
        if host_filter is not None:  # non-fused paths: filter on the host
            keep = np.isin(c, host_filter)
            r, c, v = r[keep], c[keep], v[keep]
        return r, c, v

    # ------------------------------------------------ column-axis reads
    def query_cols(self, col_ids: np.ndarray, max_return: int = 256):
        """Point COLUMN queries via the transpose sibling: all
        (row, col, val) whose col is in ``col_ids`` — same bloom/fence
        fused path a row query gets, axes swapped back on return."""
        self._check_open()
        if self.t_store is None:
            raise ValueError(
                f"table {self.name!r} has no transpose sibling "
                "(ShardedTable(transpose=True))")
        tr, tc, tv = self.t_store.query_rows(col_ids, max_return=max_return)
        return tc, tr, tv  # sibling rows ARE our cols (and vice versa)

    def scan_col_range(self, lo: int, hi: int, width: int = 64,
                       row_filter: np.ndarray = None):
        """Column-range scan ``lo <= col < hi`` via the transpose
        sibling's fused fence-to-fence scan — O(selectivity), not the
        O(nnz) full-scan-and-filter a plain table needs. Returns
        (rows, cols, vals) sorted lex by (col, row); ``row_filter``
        pushes a residual row id set into the sibling's dispatch."""
        self._check_open()
        if self.t_store is None:
            raise ValueError(
                f"table {self.name!r} has no transpose sibling "
                "(ShardedTable(transpose=True))")
        tr, tc, tv = self.t_store.scan_range(lo, hi, width=width,
                                             col_filter=row_filter)
        return tc, tr, tv  # sibling rows ARE our cols (and vice versa)

    def scan_shard(self, s: int):
        """One shard's combined sorted triples (LSM; no flush)."""
        self._check_open()
        if self.engine != "lsm":
            raise ValueError("scan_shard() requires engine='lsm'")
        mem_n = int(self._mem_n[s])
        mh = self._mem_host(s)
        if mh is None and mem_n:
            mem = (self._mem_r[s], self._mem_c[s], self._mem_v[s])
        else:
            mem = (None, None, None)
        return self._runs.scan_shard(s, *mem, mem_n, mem_host=mh)

    def scan(self):
        """Full-table scan -> (row_ids, col_ids, vals), sorted per shard."""
        self._check_open()
        self._c_full_scans.inc()
        if self.engine == "lsm":
            parts = [self.scan_shard(s) for s in range(self.S)]
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]),
                    np.concatenate([p[2] for p in parts]))
        self.flush()
        rows = np.asarray(self.tablets.rows)
        cols = np.asarray(self.tablets.cols)
        vals = np.asarray(self.tablets.vals)
        n = np.asarray(self.tablets.n)
        keep = np.arange(rows.shape[1])[None, :] < n[:, None]
        return rows[keep], cols[keep], vals[keep]
