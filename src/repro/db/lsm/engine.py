"""Leveled LSM run structure — the multi-run tablet server storage engine.

Replaces the single-sorted-run tablet with Accumulo's actual layout:

  memtable (unsorted, in ``ShardedTable``)
     │ minor compaction: sort + dedup, O(m log m) — NOT O(table capacity)
     ▼
  L0: up to ``l0_slots`` independent sorted runs of memtable size
     │ major compaction when L0 fills: k-way merge via the Pallas
     │ ``merge_rank`` kernel (``kernels.merge_rank.kway_merge``)
     ▼
  L1..Ld: one geometrically larger sorted run per level (static
          capacities, so every device op is jit-compatible)

Each run carries a packed-uint32 bloom filter over its row ids and fence
pointers (block-start row ids). Point reads probe runs newest→oldest,
skipping runs by bloom/row-range, bracketing the rank search to one fence
block — no flush required. Combiner semantics (``db.iterators``) hold
across any flush/compaction schedule because every merge preserves age
order within equal-key groups and every dedup applies the same combiner.

All state is stacked [S, ...] across shards; flushes and compactions are
vmapped so the S simulated tablet servers advance in lockstep (one hot
shard compacts its peers early — harmless, entries just move down a level).
"""
from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels.common import I32_MAX, INTERPRET
from ...kernels.merge_rank import kway_merge
from .bloom import bloom_build, bloom_maybe_contains, fence_build, num_words


def fence_block(cap: int) -> int:
    """Fence block size: small enough to bracket, large enough to amortize."""
    if cap < 32:
        return max(1, cap // 2)
    return max(16, min(1024, cap // 16))


def plan_levels(capacity_per_shard: int, mem_cap: int, l0_slots: int,
                fanout: int) -> List[int]:
    """Static per-level run capacities L1..Ld (geometric; deepest holds
    everything the structure can legally contain)."""
    need = l0_slots * mem_cap  # max entries a full L0 pushes down
    caps: List[int] = []
    c = need  # L1 absorbs exactly one L0's worth -> cheap frequent merges
    while c < capacity_per_shard:
        caps.append(c)
        c *= fanout
    caps.append(max(capacity_per_shard, need + sum(caps)))
    return caps


# ---------------------------------------------------------------- device ops
def _sort_dedup(r, c, v, combiner: str):
    """Sort one buffer lex by (row, col) (stable → age order kept), apply
    the combiner, compact valid entries to the front. Returns (r, c, v, n)."""
    from ..kvstore import _dedup_combine  # shared with the legacy engine

    cap = r.shape[0]
    order = jnp.lexsort((c, r))
    sr, sc, sv = r[order], c[order], v[order]
    keep, out_v = _dedup_combine(sr, sc, sv, combiner)
    pos = jnp.cumsum(keep) - 1
    idx = jnp.where(keep, pos, cap)
    return (
        jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sr, mode="drop"),
        jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sc, mode="drop"),
        jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop"),
        keep.sum().astype(jnp.int32),
    )


@functools.lru_cache(maxsize=None)
def _flush_fn(combiner: str, n_words: int, block: int):
    """jit(vmap): memtable [S, m] -> one sorted+deduped L0 run per shard,
    with bloom + fence metadata. Cost O(m log m) per shard."""

    def one(r, c, v):
        rr, cc, vv, n = _sort_dedup(r, c, v, combiner)
        return (rr, cc, vv, n, bloom_build(rr, n_words),
                fence_build(rr, block), rr[0], rr[jnp.maximum(n - 1, 0)])

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=None)
def _write_slot_fn():
    """Write a flushed run into L0 slot ``slot`` (traced scalar)."""

    def write(l0_r, l0_c, l0_v, l0_b, l0_f, rr, cc, vv, bb, ff, slot):
        return (l0_r.at[:, slot].set(rr), l0_c.at[:, slot].set(cc),
                l0_v.at[:, slot].set(vv), l0_b.at[:, slot].set(bb),
                l0_f.at[:, slot].set(ff))

    return jax.jit(write)


@functools.lru_cache(maxsize=None)
def _compact_fn(combiner: str, use_pallas: bool, out_cap: int, n_words: int,
                block: int):
    """jit(vmap): k-way merge L0 runs + levels 1..d into level d.

    Inputs per shard: l0 [K0, m] plus a tuple of level runs ordered
    DEEPEST FIRST (deepest = oldest). kway_merge keeps age order within
    equal-key groups, so one dedup pass applies the combiner exactly.
    """

    def one(l0_r, l0_c, l0_v, lvls):
        runs = [lv for lv in lvls]
        runs += [(l0_r[k], l0_c[k], l0_v[k]) for k in range(l0_r.shape[0])]
        mr, mc, mv = kway_merge(runs, use_pallas=use_pallas,
                                interpret=INTERPRET)
        from ..kvstore import _dedup_combine
        keep, out_v = _dedup_combine(mr, mc, mv, combiner)
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, out_cap)
        rr = jnp.full((out_cap,), I32_MAX, jnp.int32).at[idx].set(mr, mode="drop")
        cc = jnp.full((out_cap,), I32_MAX, jnp.int32).at[idx].set(mc, mode="drop")
        vv = jnp.zeros((out_cap,), jnp.float32).at[idx].set(out_v, mode="drop")
        n = keep.sum().astype(jnp.int32)
        return (rr, cc, vv, n, bloom_build(rr, n_words),
                fence_build(rr, block), rr[0], rr[jnp.maximum(n - 1, 0)])

    return jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0)))


@functools.partial(jax.jit, static_argnames=("max_return", "block"))
def run_query_rows(rows, cols, vals, fence, q, max_return: int, block: int):
    """Fence-bracketed point row query against one sorted run.

    The fence array (block-start row ids) locates the block holding each
    query's start/end rank; the exact rank search then touches only that
    block (+1 entry of spill) — the in-memory analogue of reading a single
    index-addressed RFile block. Returns (cols[Q, max_return],
    vals[Q, max_return], ok[Q, max_return], counts[Q]).
    """
    cap = rows.shape[0]
    w = block + 1

    def bracketed(qi, side):
        fi = jnp.searchsorted(fence, qi, side=side)
        base = jnp.clip(jnp.maximum(fi - 1, 0) * block, 0, cap - w)
        win = jax.lax.dynamic_slice(rows, (base,), (w,))
        return (base + jnp.searchsorted(win, qi, side=side)).astype(jnp.int32)

    start = jax.vmap(lambda qi: bracketed(qi, "left"))(q)
    end = jax.vmap(lambda qi: bracketed(qi, "right"))(q)
    idx = start[:, None] + jnp.arange(max_return, dtype=jnp.int32)[None, :]
    ok = idx < end[:, None]
    idxc = jnp.clip(idx, 0, cap - 1)
    return cols[idxc], vals[idxc], ok, end - start


@functools.partial(jax.jit, static_argnames=("max_return", "block"))
def run_query_gated(rows, cols, vals, fence, bloom, q, max_return: int,
                    block: int):
    """Bloom-gated run query in ONE dispatch: probe the bloom filter and,
    only when some queried row may be present (lax.cond — the search branch
    is genuinely skipped otherwise), run the fence-bracketed rank search.
    Returns (any_hit, cols, vals, ok, counts). Launch these for every run
    back-to-back and sync once — the read path costs one round-trip, not
    one per run."""
    any_hit = jnp.any(bloom_maybe_contains(bloom, q))

    def probe(_):
        return run_query_rows(rows, cols, vals, fence, q, max_return, block)

    def skip(_):
        nq = q.shape[0]
        return (jnp.zeros((nq, max_return), jnp.int32),
                jnp.zeros((nq, max_return), jnp.float32),
                jnp.zeros((nq, max_return), jnp.bool_),
                jnp.zeros((nq,), jnp.int32))

    return (any_hit,) + jax.lax.cond(any_hit, probe, skip, None)


def combine_triples(r: np.ndarray, c: np.ndarray, v: np.ndarray,
                    age: np.ndarray, combiner: str):
    """Host-side cross-run combine: sort candidates by (row, col, age) and
    reduce each key group per the combiner. Each source is already deduped
    (or, for the raw memtable, in append order with a constant age — the
    stable sort keeps append order, so 'last' still wins correctly)."""
    if len(r) == 0:
        z = np.zeros(0, np.int32)
        return z, z.copy(), np.zeros(0, np.float32)
    order = np.lexsort((age, c, r))
    r, c, v = r[order], c[order], v[order]
    new = np.ones(len(r), bool)
    new[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    starts = np.flatnonzero(new)
    if combiner == "last":
        ends = np.append(starts[1:], len(r)) - 1
        return r[starts], c[starts], v[ends]
    if combiner == "sum":
        vv = np.add.reduceat(v, starts)
    elif combiner == "min":
        vv = np.minimum.reduceat(v, starts)
    elif combiner == "max":
        vv = np.maximum.reduceat(v, starts)
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    return r[starts], c[starts], vv.astype(np.float32)


# ------------------------------------------------------------------ engine
class LSMRuns:
    """The leveled run structure for S shards (no memtable — that stays in
    ``ShardedTable`` and is handed to ``flush_memtable``/read methods)."""

    def __init__(self, num_shards: int, capacity_per_shard: int,
                 mem_cap: int, combiner: str, use_pallas: bool = False,
                 l0_slots: int = 4, fanout: int = 4):
        assert mem_cap >= 8, "LSM memtable too small to index"
        self.S = num_shards
        self.cap = capacity_per_shard
        self.mem_cap = mem_cap
        self.combiner = combiner
        self.use_pallas = use_pallas
        self.K0 = l0_slots
        self.fanout = fanout
        self.level_caps = plan_levels(capacity_per_shard, mem_cap, l0_slots,
                                      fanout)
        S, m, K0 = num_shards, mem_cap, l0_slots
        self._w0 = num_words(m)
        self._b0 = fence_block(m)
        nblk0 = -(-m // self._b0)
        self.l0_rows = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_cols = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_vals = jnp.zeros((S, K0, m), jnp.float32)
        self.l0_bloom = jnp.zeros((S, K0, self._w0), jnp.uint32)
        self.l0_fence = jnp.full((S, K0, nblk0), I32_MAX, jnp.int32)
        self.l0_n = np.zeros((S, K0), np.int64)
        # host-side row ranges per run: skip runs without device roundtrips
        self.l0_min = np.full((S, K0), I32_MAX, np.int64)
        self.l0_max = np.full((S, K0), -1, np.int64)
        self.l0_used = 0
        self.levels: List[dict] = []
        for cap in self.level_caps:
            w, b = num_words(cap), fence_block(cap)
            self.levels.append({
                "cap": cap, "words": w, "block": b,
                "rows": jnp.full((S, cap), I32_MAX, jnp.int32),
                "cols": jnp.full((S, cap), I32_MAX, jnp.int32),
                "vals": jnp.zeros((S, cap), jnp.float32),
                "bloom": jnp.zeros((S, w), jnp.uint32),
                "fence": jnp.full((S, -(-cap // b)), I32_MAX, jnp.int32),
                "n": np.zeros((S,), np.int64),
                "minr": np.full((S,), I32_MAX, np.int64),
                "maxr": np.full((S,), -1, np.int64),
            })
        # read-path observability (tests assert blooms actually skip work)
        self.stats = {"flushes": 0, "major_compactions": 0,
                      "runs_probed": 0, "runs_skipped": 0}
        # per-run sliced views of the stacked arrays (slicing copies ~MBs
        # eagerly per query otherwise); invalidated on flush/compaction
        self._view_cache: dict = {}

    def warmup(self, mem_r, mem_c, mem_v) -> None:
        """Compile the flush + every compaction depth's graph by running
        them on the current (typically empty) state; results are discarded,
        so no state mutates. Keeps jit time out of benchmark windows."""
        rr, cc, vv, n, bb, ff, _, _ = _flush_fn(
            self.combiner, self._w0, self._b0)(mem_r, mem_c, mem_v)
        _write_slot_fn()(self.l0_rows, self.l0_cols, self.l0_vals,
                         self.l0_bloom, self.l0_fence, rr, cc, vv, bb, ff,
                         jnp.asarray(0, jnp.int32))
        for d, lv in enumerate(self.levels):
            lvls = tuple((self.levels[i]["rows"], self.levels[i]["cols"],
                          self.levels[i]["vals"]) for i in range(d, -1, -1))
            out = _compact_fn(self.combiner, self.use_pallas, lv["cap"],
                              lv["words"], lv["block"])(
                self.l0_rows, self.l0_cols, self.l0_vals, lvls)
            jax.block_until_ready(out)

    # ----------------------------------------------------------- write path
    def flush_memtable(self, mem_r, mem_c, mem_v) -> None:
        """Minor compaction: memtable -> one L0 run per shard, O(m log m).
        Triggers a major compaction when L0 is full. May raise
        OverflowError (capacity back-pressure, like the legacy engine)."""
        if self.l0_used == self.K0:
            self.major_compact()
        rr, cc, vv, n, bb, ff, mn, mx = _flush_fn(
            self.combiner, self._w0, self._b0)(mem_r, mem_c, mem_v)
        (self.l0_rows, self.l0_cols, self.l0_vals, self.l0_bloom,
         self.l0_fence) = _write_slot_fn()(
            self.l0_rows, self.l0_cols, self.l0_vals, self.l0_bloom,
            self.l0_fence, rr, cc, vv, bb, ff,
            jnp.asarray(self.l0_used, jnp.int32))
        self.l0_n[:, self.l0_used] = np.asarray(n)
        self.l0_min[:, self.l0_used] = np.asarray(mn)
        self.l0_max[:, self.l0_used] = np.asarray(mx)
        # all L0 slot views alias the re-written stacked arrays; drop them
        self._view_cache = {k: v for k, v in self._view_cache.items()
                            if k[0] != "l0"}
        self.l0_used += 1
        self.stats["flushes"] += 1
        if self.l0_used == self.K0:
            self.major_compact()

    def _pick_depth(self) -> int:
        """Smallest level whose capacity bounds the (pre-dedup) merge size
        for every shard; the deepest level is the fallback."""
        bound = self.l0_n.sum(axis=1)  # [S]
        for d, lv in enumerate(self.levels):
            bound = bound + lv["n"]
            if int(bound.max()) <= lv["cap"]:
                return d
        return len(self.levels) - 1

    def major_compact(self) -> None:
        """Size-triggered major compaction: k-way merge all L0 runs and
        levels 1..d into level d (Pallas merge_rank under ``use_pallas``)."""
        if self.l0_used == 0:
            return
        d = self._pick_depth()
        target = self.levels[d]
        # deepest first = oldest first (kway_merge contract)
        lvls = tuple((self.levels[i]["rows"], self.levels[i]["cols"],
                      self.levels[i]["vals"]) for i in range(d, -1, -1))
        rr, cc, vv, n, bb, ff, mn, mx = _compact_fn(
            self.combiner, self.use_pallas, target["cap"], target["words"],
            target["block"])(self.l0_rows, self.l0_cols, self.l0_vals, lvls)
        n_host = np.asarray(n)
        if d == len(self.levels) - 1 and int(n_host.max()) > self.cap:
            raise OverflowError(
                f"LSM shard overflow: {int(n_host.max())} > {self.cap}")
        target.update(rows=rr, cols=cc, vals=vv, bloom=bb, fence=ff,
                      n=n_host.astype(np.int64),
                      minr=np.asarray(mn).astype(np.int64),
                      maxr=np.asarray(mx).astype(np.int64))
        S, K0, m = self.S, self.K0, self.mem_cap
        self.l0_rows = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_cols = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_vals = jnp.zeros((S, K0, m), jnp.float32)
        self.l0_bloom = jnp.zeros((S, K0, self._w0), jnp.uint32)
        self.l0_fence = jnp.full_like(self.l0_fence, I32_MAX)
        self.l0_n[:] = 0
        self.l0_min[:] = I32_MAX
        self.l0_max[:] = -1
        self.l0_used = 0
        for i in range(d):
            lv = self.levels[i]
            lv["rows"] = jnp.full_like(lv["rows"], I32_MAX)
            lv["cols"] = jnp.full_like(lv["cols"], I32_MAX)
            lv["vals"] = jnp.zeros_like(lv["vals"])
            lv["bloom"] = jnp.zeros_like(lv["bloom"])
            lv["fence"] = jnp.full_like(lv["fence"], I32_MAX)
            lv["n"][:] = 0
            lv["minr"][:] = I32_MAX
            lv["maxr"][:] = -1
        self._view_cache.clear()
        self.stats["major_compactions"] += 1

    # ------------------------------------------------------------ read path
    def _iter_runs_oldest_first(self, s: int):
        """Yield (rows, cols, vals, fence, bloom, n, block, minr, maxr)
        per-run views of shard ``s``, oldest (deepest level) to newest
        (latest L0 slot)."""
        for i in range(len(self.levels) - 1, -1, -1):
            lv = self.levels[i]
            if lv["n"][s]:
                key = ("lvl", i, s)
                view = self._view_cache.get(key)
                if view is None:
                    view = (lv["rows"][s], lv["cols"][s], lv["vals"][s],
                            lv["fence"][s], lv["bloom"][s])
                    self._view_cache[key] = view
                yield view + (int(lv["n"][s]), lv["block"],
                              int(lv["minr"][s]), int(lv["maxr"][s]))
        for k in range(self.l0_used):
            if self.l0_n[s, k]:
                key = ("l0", k, s)
                view = self._view_cache.get(key)
                if view is None:
                    view = (self.l0_rows[s, k], self.l0_cols[s, k],
                            self.l0_vals[s, k], self.l0_fence[s, k],
                            self.l0_bloom[s, k])
                    self._view_cache[key] = view
                yield view + (int(self.l0_n[s, k]), self._b0,
                              int(self.l0_min[s, k]), int(self.l0_max[s, k]))

    def query_shard(self, s: int, q: np.ndarray, mem_r, mem_c, mem_v,
                    mem_n: int, max_return: int,
                    mem_host: Optional[Tuple[np.ndarray, ...]] = None):
        """Point row queries for one shard: probe runs oldest→newest plus
        the memtable tail, combine across sources. NO flush happens.

        Two-phase: launch the bloom-gated query of every candidate run
        asynchronously, then sync once and harvest — read latency is one
        device round-trip regardless of run count. ``mem_host`` is an
        optional host mirror of the shard's memtable (avoids pulling the
        device buffer)."""
        q_dev = jnp.asarray(q)
        q_sorted = np.sort(q)
        launched = []
        age = 0
        for rows, cols, vals, fence, bloom, n, block, minr, maxr in \
                self._iter_runs_oldest_first(s):
            age += 1
            if q_sorted[-1] < minr or q_sorted[0] > maxr:
                self.stats["runs_skipped"] += 1
                continue
            out = run_query_gated(rows, cols, vals, fence, bloom, q_dev,
                                  max_return, block)
            launched.append((age, (rows, cols, vals, fence, block), out))
        cand_r, cand_c, cand_v, cand_a = [], [], [], []
        for age_i, run, (any_hit, cols_o, vals_o, ok, cnt) in launched:
            if not bool(any_hit):  # bloom says absent — search was skipped
                self.stats["runs_skipped"] += 1
                continue
            self.stats["runs_probed"] += 1
            cnt = np.asarray(cnt)
            if cnt.max(initial=0) > max_return:  # widen + retry (scanner)
                rows, cols, vals, fence, block = run
                cols_o, vals_o, ok, cnt = run_query_rows(
                    rows, cols, vals, fence, q_dev, int(cnt.max()), block)
            ok = np.asarray(ok)
            cols_o, vals_o = np.asarray(cols_o), np.asarray(vals_o)
            qi, ki = np.nonzero(ok)
            cand_r.append(q[qi]); cand_c.append(cols_o[qi, ki])
            cand_v.append(vals_o[qi, ki])
            cand_a.append(np.full(len(qi), age_i, np.int32))
        if mem_n:
            if mem_host is not None:
                mr, mc, mv = mem_host
            else:
                mr = np.asarray(mem_r[:mem_n])
                mc = np.asarray(mem_c[:mem_n])
                mv = np.asarray(mem_v[:mem_n])
            mask = np.isin(mr, q)
            if mask.any():
                cand_r.append(mr[mask])
                cand_c.append(mc[mask])
                cand_v.append(mv[mask])
                cand_a.append(np.full(int(mask.sum()), age + 1, np.int32))
        if not cand_r:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        return combine_triples(np.concatenate(cand_r).astype(np.int32),
                               np.concatenate(cand_c).astype(np.int32),
                               np.concatenate(cand_v).astype(np.float32),
                               np.concatenate(cand_a), self.combiner)

    def scan_shard(self, s: int, mem_r, mem_c, mem_v, mem_n: int,
                   mem_host: Optional[Tuple[np.ndarray, ...]] = None):
        """All (row, col, val) of one shard, combined across runs + memtable,
        sorted lex by (row, col). NO flush happens."""
        cand = []
        age = 0
        for rows, cols, vals, fence, bloom, n, block, minr, maxr in \
                self._iter_runs_oldest_first(s):
            age += 1
            cand.append((np.asarray(rows[:n]), np.asarray(cols[:n]),
                         np.asarray(vals[:n]),
                         np.full(n, age, np.int32)))
        if mem_n:
            if mem_host is not None:
                mr, mc, mv = mem_host
            else:
                mr = np.asarray(mem_r[:mem_n])
                mc = np.asarray(mem_c[:mem_n])
                mv = np.asarray(mem_v[:mem_n])
            cand.append((mr, mc, mv, np.full(len(mr), age + 1, np.int32)))
        if not cand:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        r = np.concatenate([x[0] for x in cand]).astype(np.int32)
        c = np.concatenate([x[1] for x in cand]).astype(np.int32)
        v = np.concatenate([x[2] for x in cand]).astype(np.float32)
        a = np.concatenate([x[3] for x in cand])
        return combine_triples(r, c, v, a, self.combiner)

    # --------------------------------------------------------- persistence
    def state_arrays(self) -> dict:
        """Flat name -> np.ndarray map of all run state (for snapshots)."""
        out = {
            "l0_rows": np.asarray(self.l0_rows),
            "l0_cols": np.asarray(self.l0_cols),
            "l0_vals": np.asarray(self.l0_vals),
            "l0_n": self.l0_n.copy(),
            "l0_used": np.asarray(self.l0_used),
        }
        for i, lv in enumerate(self.levels):
            out[f"lvl{i}_rows"] = np.asarray(lv["rows"])
            out[f"lvl{i}_cols"] = np.asarray(lv["cols"])
            out[f"lvl{i}_vals"] = np.asarray(lv["vals"])
            out[f"lvl{i}_n"] = lv["n"].copy()
        return out

    def load_state(self, arrs: dict) -> None:
        """Restore from ``state_arrays`` output; blooms and fences are
        derived data and get rebuilt (cheaper than persisting them)."""
        self._view_cache.clear()
        l0_rows_np = np.asarray(arrs["l0_rows"])
        self.l0_rows = jnp.asarray(l0_rows_np)
        self.l0_cols = jnp.asarray(arrs["l0_cols"])
        self.l0_vals = jnp.asarray(arrs["l0_vals"])
        self.l0_n = np.asarray(arrs["l0_n"]).astype(np.int64)
        self.l0_used = int(arrs["l0_used"])
        bloom_f = jax.jit(jax.vmap(jax.vmap(
            lambda r: bloom_build(r, self._w0))))
        self.l0_bloom = bloom_f(self.l0_rows)
        self.l0_fence = self.l0_rows[:, :, ::self._b0]
        self.l0_min = l0_rows_np[:, :, 0].astype(np.int64)
        last = np.maximum(self.l0_n - 1, 0)
        self.l0_max = np.take_along_axis(
            l0_rows_np, last[:, :, None].astype(np.int64), axis=2
        )[:, :, 0].astype(np.int64)
        for i, lv in enumerate(self.levels):
            rows_np = np.asarray(arrs[f"lvl{i}_rows"])
            lv["rows"] = jnp.asarray(rows_np)
            lv["cols"] = jnp.asarray(arrs[f"lvl{i}_cols"])
            lv["vals"] = jnp.asarray(arrs[f"lvl{i}_vals"])
            lv["n"] = np.asarray(arrs[f"lvl{i}_n"]).astype(np.int64)
            w = lv["words"]
            lv["bloom"] = jax.jit(jax.vmap(
                functools.partial(bloom_build, n_words=w)))(lv["rows"])
            lv["fence"] = lv["rows"][:, ::lv["block"]]
            lv["minr"] = rows_np[:, 0].astype(np.int64)
            last = np.maximum(lv["n"] - 1, 0).astype(np.int64)
            lv["maxr"] = rows_np[np.arange(self.S), last].astype(np.int64)
