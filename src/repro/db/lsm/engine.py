"""Leveled LSM run structure — the multi-run tablet server storage engine.

Replaces the single-sorted-run tablet with Accumulo's actual layout:

  memtable (unsorted, in ``ShardedTable``)
     │ minor compaction: sort + dedup, O(m log m) — NOT O(table capacity)
     ▼
  L0: up to ``l0_slots`` independent sorted runs of memtable size
     │ major compaction when L0 fills: k-way merge via the Pallas
     │ ``merge_rank`` kernel (``kernels.merge_rank.kway_merge``)
     ▼
  L1..Ld: one geometrically larger sorted run per level (static
          capacities, so every device op is jit-compatible)

Each run carries a packed-uint32 bloom filter over its row ids (sized per
level — deep levels absorb most negative lookups) and fence pointers
(block-start row ids). Combiner semantics (``db.iterators``) hold across
any flush/compaction schedule because every merge preserves age order
within equal-key groups and every dedup applies the same combiner.

Two read paths serve point queries (neither ever flushes):

* **fused** (default, ``query_shard_fused``): the entire shard — every
  leveled run, the whole L0 stack, and the memtable tail — is searched by
  ONE jitted dispatch per query TILE. Runs keep their static stacked
  shapes (levels are distinct-capacity buckets, L0 is already a [K0, m]
  batch; empty slots are inert I32_MAX padding, so no re-bucketing is
  ever needed), each run's fence-bracketed rank search is block
  bloom-gated (``lax.cond`` — a tile that misses a run's filter skips its
  probe entirely), and the cross-run age-ordered combine happens
  on-device via the batched ``merge_rank`` rank+scatter merge. Batches
  larger than the tile split into fixed-size blocks that reuse ONE jit
  cache entry: ceil(Q/tile) dispatches, never a per-run fallback.
* **per-run** (``query_shard``): one bloom-gated kernel launch per
  resident run, combined on the host. Kept as the A/B baseline
  (``fused_reads=False``) and for the stale-mirror recovery corners.

All state is stacked [S, ...] across shards; flushes and compactions are
vmapped so the S simulated tablet servers advance in lockstep (one hot
shard compacts its peers early — harmless, entries just move down a level).
"""
from __future__ import annotations

import functools
from time import perf_counter
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels.common import I32_MAX, INTERPRET
from ...obs import default_registry, default_tracer
from ...kernels.merge_rank import kway_merge, merge_combine_rows
from ...kernels.sorted_search import (sorted_search_batched,
                                      sorted_search_endpoints)
from .bloom import (BITS_PER_KEY, MAX_HASHES, NUM_HASHES, bloom_build,
                    bloom_maybe_contains, bloom_maybe_contains_batch,
                    fence_build, num_words, theoretical_fp_rate)


def fence_block(cap: int) -> int:
    """Fence block size: small enough to bracket, large enough to amortize."""
    if cap < 32:
        return max(1, cap // 2)
    return max(16, min(1024, cap // 16))


def plan_levels(capacity_per_shard: int, mem_cap: int, l0_slots: int,
                fanout: int) -> List[int]:
    """Static per-level run capacities L1..Ld (geometric; deepest holds
    everything the structure can legally contain)."""
    need = l0_slots * mem_cap  # max entries a full L0 pushes down
    caps: List[int] = []
    c = need  # L1 absorbs exactly one L0's worth -> cheap frequent merges
    while c < capacity_per_shard:
        caps.append(c)
        c *= fanout
    caps.append(max(capacity_per_shard, need + sum(caps)))
    return caps


def _per_level(spec: Union[int, Sequence[int]], n_levels: int) -> Tuple[int, ...]:
    """Expand a scalar-or-sequence sizing spec to one value per level.

    A sequence shorter than the level count repeats its last entry for the
    deeper levels (so ``(8, 12, 16)`` means: L1 8 bits, L2 12, L3+ 16)."""
    if isinstance(spec, (int, np.integer)):
        return (int(spec),) * n_levels
    spec = tuple(int(x) for x in spec)
    if not spec:
        raise ValueError("empty bloom sizing spec")
    return tuple(spec[min(i, len(spec) - 1)] for i in range(n_levels))


def _bucket(n: int, lo: int = 8) -> int:
    """Next pow2 >= max(n, lo): static jit shapes for ragged host inputs."""
    return 1 << (max(n, lo) - 1).bit_length()


# ---------------------------------------------------------------- device ops
def _sort_dedup(r, c, v, combiner: str):
    """Sort one buffer lex by (row, col) (stable → age order kept), apply
    the combiner, compact valid entries to the front. Returns (r, c, v, n)."""
    from ..kvstore import _dedup_combine  # shared with the legacy engine

    cap = r.shape[0]
    order = jnp.lexsort((c, r))
    sr, sc, sv = r[order], c[order], v[order]
    keep, out_v = _dedup_combine(sr, sc, sv, combiner)
    pos = jnp.cumsum(keep) - 1
    idx = jnp.where(keep, pos, cap)
    return (
        jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sr, mode="drop"),
        jnp.full((cap,), I32_MAX, jnp.int32).at[idx].set(sc, mode="drop"),
        jnp.zeros((cap,), jnp.float32).at[idx].set(out_v, mode="drop"),
        keep.sum().astype(jnp.int32),
    )


@functools.lru_cache(maxsize=None)
def _flush_fn(combiner: str, n_words: int, block: int, n_hashes: int):
    """jit(vmap): memtable [S, m] -> one sorted+deduped L0 run per shard,
    with bloom + fence metadata. Cost O(m log m) per shard."""

    def one(r, c, v):
        rr, cc, vv, n = _sort_dedup(r, c, v, combiner)
        return (rr, cc, vv, n, bloom_build(rr, n_words, n_hashes),
                fence_build(rr, block), rr[0], rr[jnp.maximum(n - 1, 0)])

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=None)
def _bloom_rebuild_fn(n_words: int, n_hashes: int, nested: bool):
    """jit: rebuild blooms for stacked runs on snapshot load — cached at
    module level so repeated ``recover()`` calls (crash-fuzz loops, test
    suites) reuse the compiled graph instead of re-tracing per call."""
    one = functools.partial(bloom_build, n_words=n_words, n_hashes=n_hashes)
    f = jax.vmap(jax.vmap(one)) if nested else jax.vmap(one)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _write_slot_fn():
    """Write each shard's flushed run into ITS next free L0 slot
    (``slot`` is a traced [S] vector — shards fill independently). A shard
    whose slot index equals K0 (full L0, nothing incoming) drops the
    write."""

    def write(l0_r, l0_c, l0_v, l0_b, l0_f, rr, cc, vv, bb, ff, slot):
        s = jnp.arange(l0_r.shape[0])
        return (l0_r.at[s, slot].set(rr, mode="drop"),
                l0_c.at[s, slot].set(cc, mode="drop"),
                l0_v.at[s, slot].set(vv, mode="drop"),
                l0_b.at[s, slot].set(bb, mode="drop"),
                l0_f.at[s, slot].set(ff, mode="drop"))

    return jax.jit(write)


@functools.lru_cache(maxsize=None)
def _compact_fn(combiner: str, use_pallas: bool, out_cap: int, n_words: int,
                block: int, n_hashes: int):
    """jit(vmap): k-way merge L0 runs + levels 1..d into level d.

    Inputs per shard: l0 [K0, m] plus a tuple of level runs ordered
    DEEPEST FIRST (deepest = oldest). kway_merge keeps age order within
    equal-key groups, so one dedup pass applies the combiner exactly.
    """

    def one(l0_r, l0_c, l0_v, lvls):
        runs = [lv for lv in lvls]
        runs += [(l0_r[k], l0_c[k], l0_v[k]) for k in range(l0_r.shape[0])]
        mr, mc, mv = kway_merge(runs, use_pallas=use_pallas,
                                interpret=INTERPRET)
        from ..kvstore import _dedup_combine
        keep, out_v = _dedup_combine(mr, mc, mv, combiner)
        pos = jnp.cumsum(keep) - 1
        idx = jnp.where(keep, pos, out_cap)
        rr = jnp.full((out_cap,), I32_MAX, jnp.int32).at[idx].set(mr, mode="drop")
        cc = jnp.full((out_cap,), I32_MAX, jnp.int32).at[idx].set(mc, mode="drop")
        vv = jnp.zeros((out_cap,), jnp.float32).at[idx].set(out_v, mode="drop")
        n = keep.sum().astype(jnp.int32)
        return (rr, cc, vv, n, bloom_build(rr, n_words, n_hashes),
                fence_build(rr, block), rr[0], rr[jnp.maximum(n - 1, 0)])

    return jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 0)))


@functools.partial(jax.jit, static_argnames=("max_return", "block"))
def run_query_rows(rows, cols, vals, fence, q, max_return: int, block: int):
    """Fence-bracketed point row query against one sorted run.

    The fence array (block-start row ids) locates the block holding each
    query's start/end rank; the exact rank search then touches only that
    block (+1 entry of spill) — the in-memory analogue of reading a single
    index-addressed RFile block. Returns (cols[Q, max_return],
    vals[Q, max_return], ok[Q, max_return], counts[Q]).
    """
    cap = rows.shape[0]
    w = block + 1

    def bracketed(qi, side):
        fi = jnp.searchsorted(fence, qi, side=side)
        base = jnp.clip(jnp.maximum(fi - 1, 0) * block, 0, cap - w)
        win = jax.lax.dynamic_slice(rows, (base,), (w,))
        return (base + jnp.searchsorted(win, qi, side=side)).astype(jnp.int32)

    start = jax.vmap(lambda qi: bracketed(qi, "left"))(q)
    end = jax.vmap(lambda qi: bracketed(qi, "right"))(q)
    idx = start[:, None] + jnp.arange(max_return, dtype=jnp.int32)[None, :]
    ok = idx < end[:, None]
    idxc = jnp.clip(idx, 0, cap - 1)
    return cols[idxc], vals[idxc], ok, end - start


@functools.partial(jax.jit, static_argnames=("max_return", "block", "n_hashes"))
def run_query_gated(rows, cols, vals, fence, bloom, q, max_return: int,
                    block: int, n_hashes: int = NUM_HASHES):
    """Bloom-gated run query in ONE dispatch: probe the bloom filter and,
    only when some queried row may be present (lax.cond — the search branch
    is genuinely skipped otherwise), run the fence-bracketed rank search.
    Returns (any_hit, cols, vals, ok, counts). The per-run baseline path
    launches these for every run back-to-back and syncs once; the fused
    path replaces the N launches with one."""
    any_hit = jnp.any(bloom_maybe_contains(bloom, q, n_hashes))

    def probe(_):
        return run_query_rows(rows, cols, vals, fence, q, max_return, block)

    def skip(_):
        nq = q.shape[0]
        return (jnp.zeros((nq, max_return), jnp.int32),
                jnp.zeros((nq, max_return), jnp.float32),
                jnp.zeros((nq, max_return), jnp.bool_),
                jnp.zeros((nq,), jnp.int32))

    return (any_hit,) + jax.lax.cond(any_hit, probe, skip, None)


# ----------------------------------------------------------- fused read path
def _probe_stack(rows, cols, vals, fences, q, max_return: int, block: int,
                 use_pallas: bool):
    """Fence-bracketed rank search of ``q`` against K stacked runs, traced
    inline (callers jit). rows/cols/vals [K, cap], fences [K, nb], q [Q].
    Returns (cols[K, Q, R], vals[K, Q, R], ok[K, Q, R], counts[K, Q]).

    Under ``use_pallas`` the fence rank search runs through the batched
    Pallas ``sorted_search`` kernel (one launch for all K fence arrays).
    The run axis is unrolled (K is static and small): vmapping it turns
    the per-query ``dynamic_slice`` window reads into a generic gather,
    which XLA:CPU lowers ~16x slower — the unrolled form keeps the same
    single dispatch with the fast slice lowering.
    """
    n_k, cap = rows.shape
    w = block + 1
    if use_pallas:
        fl = sorted_search_batched(fences, q, "left", interpret=INTERPRET)
        fr = sorted_search_batched(fences, q, "right", interpret=INTERPRET)
    else:
        fl = jnp.stack([jnp.searchsorted(fences[k], q, side="left")
                        .astype(jnp.int32) for k in range(n_k)])
        fr = jnp.stack([jnp.searchsorted(fences[k], q, side="right")
                        .astype(jnp.int32) for k in range(n_k)])
    iota = jnp.arange(max_return, dtype=jnp.int32)
    c_o, v_o, ok_o, cnt_o = [], [], [], []
    for k in range(n_k):
        rws = rows[k]

        def bracket(qi, fi, side):
            base = jnp.clip(jnp.maximum(fi - 1, 0) * block, 0, cap - w)
            win = jax.lax.dynamic_slice(rws, (base,), (w,))
            return (base + jnp.searchsorted(win, qi, side=side)
                    ).astype(jnp.int32)

        start = jax.vmap(lambda qi, fi: bracket(qi, fi, "left"))(q, fl[k])
        end = jax.vmap(lambda qi, fi: bracket(qi, fi, "right"))(q, fr[k])
        idx = start[:, None] + iota[None, :]
        idxc = jnp.clip(idx, 0, cap - 1)
        c_o.append(cols[k][idxc])
        v_o.append(vals[k][idxc])
        ok_o.append(idx < end[:, None])
        cnt_o.append(end - start)
    return (jnp.stack(c_o), jnp.stack(v_o), jnp.stack(ok_o),
            jnp.stack(cnt_o))


@functools.lru_cache(maxsize=None)
def _fused_query_fn(combiner: str, level_blocks: Tuple[int, ...],
                    level_hashes: Tuple[int, ...], b0: int, h0: int,
                    max_return: int, mem_mode: str, pack: bool,
                    use_pallas: bool, has_filter: bool = False):
    """Build THE single-dispatch query: the resident leveled runs (deepest
    first), the used L0 slots, and (optionally) the memtable tail of one
    shard are searched and cross-run combined inside one ``jax.jit``.

    Static key = resident geometry (per-level fence blocks + bloom hash
    counts) x (max_return, mem_mode, pack, use_pallas); array shapes
    (level caps, used slots, memtable bucket, query bucket) retrace under
    the same jit. Age order: levels deepest→shallowest get ages 1..L
    (oldest data lives deepest), L0 slots L+1..L+K0 (slot k was flushed
    before slot k+1), the memtable L+K0+1 (newest). ``mem_mode``:
    ``"sorted"`` = the host pre-sorted/deduped the mirror (cheap, cached
    between inserts); ``"raw"`` = unsorted device slices, sort in-dispatch
    (the stale-mirror SPMD path); ``"none"`` = empty.

    The on-device combine orders each query's candidates by (col, age)
    and reduces equal-col groups with the combiner — exactly
    ``combine_triples`` semantics, no host work. Under ``pack`` the
    (col, age) key pair packs into ONE int32 (valid when
    id_capacity * age_padding < 2**30) and the packed keys — unique per
    query row — are merged by the batched ``merge_rank`` rank+scatter
    merge (``merge_combine_rows``: strict self-rank IS the merged
    position; Pallas ``row_rank`` kernel under ``use_pallas``) as long as
    the candidate width stays within its quadratic-compare budget; wider
    retries and unpackable geometry fall back to ``lax.sort``.

    Every run's probe is BLOCK bloom-gated: the whole query block's hit
    mask feeds a ``lax.cond``, so a block that misses a run's filter
    entirely skips that run's fence search and window gathers — with
    query tiling, a tile whose key range lands outside a run costs only
    the bloom probes.

    With ``has_filter`` the dispatch takes an extra sorted int32 column
    id set (padded with I32_MAX) and drops candidates outside it ON
    DEVICE (sorted-membership via ``searchsorted``) before the combine —
    the residual ``isin(cols)`` of a row-driven read never reaches the
    host.

    Returns (cols[Q, W], vals[Q, W], keep[Q, W], cnt_max, hits[L+K0])
    with W = n_runs * max_return; ``cnt_max`` > max_return signals the
    host to re-dispatch wider (batch-scanner semantics), and ``hits``
    reports per-run bloom verdicts for observability.
    """
    from ..kvstore import _dedup_combine

    n_levels = len(level_blocks)

    def fused(q, levels, l0, mem, filt=None):
        seg_cols, seg_vals, seg_ok, seg_age, cnts, hits = [], [], [], [], [], []
        n_q = q.shape[0]
        iota = jnp.arange(max_return, dtype=jnp.int32)

        def skip(_):
            return (jnp.zeros((n_q, max_return), jnp.int32),
                    jnp.zeros((n_q, max_return), jnp.float32),
                    jnp.zeros((n_q, max_return), jnp.bool_),
                    jnp.zeros((n_q,), jnp.int32))

        # leveled runs, deepest (oldest) first — ages 1..L
        for i, (rows, cols, vals, fence, bloom) in enumerate(levels):
            hit = bloom_maybe_contains(bloom, q, level_hashes[i])
            any_hit = jnp.any(hit)

            def probe(_, rows=rows, cols=cols, vals=vals, fence=fence,
                      blk=level_blocks[i]):
                c_o, v_o, ok, cnt = _probe_stack(
                    rows[None], cols[None], vals[None], fence[None], q,
                    max_return, blk, use_pallas)
                return c_o[0], v_o[0], ok[0], cnt[0]

            c_o, v_o, ok, cnt = jax.lax.cond(any_hit, probe, skip, None)
            seg_cols.append(c_o)
            seg_vals.append(v_o)
            seg_ok.append(ok & hit[:, None])
            seg_age.append(i + 1)
            cnts.append(cnt)
            hits.append(any_hit)
        # the used L0 slots — ages L+1..L+K0 (a slot empty for THIS shard
        # while used by a peer is inert I32_MAX padding); gated per slot,
        # same cond pattern
        l0_rows, l0_cols, l0_vals, l0_fence, l0_bloom = l0
        k0 = l0_rows.shape[0]
        if k0:
            l0_hit = bloom_maybe_contains_batch(l0_bloom, q, h0)  # [K0, Q]
            for k in range(k0):
                any_k = jnp.any(l0_hit[k])

                def probe_k(_, k=k):
                    c_o, v_o, ok, cnt = _probe_stack(
                        l0_rows[k][None], l0_cols[k][None], l0_vals[k][None],
                        l0_fence[k][None], q, max_return, b0, use_pallas)
                    return c_o[0], v_o[0], ok[0], cnt[0]

                c_o, v_o, ok, cnt = jax.lax.cond(any_k, probe_k, skip, None)
                seg_cols.append(c_o)
                seg_vals.append(v_o)
                seg_ok.append(ok & l0_hit[k][:, None])
                seg_age.append(n_levels + 1 + k)
                cnts.append(cnt)
                hits.append(any_k)
        # the memtable tail (newest): one pre-combined sorted pseudo-run
        # (intra-memtable combine commutes with the cross-run combine —
        # flush relies on the same property)
        if mem_mode != "none":
            mem_r, mem_c, mem_v = mem
            if mem_mode == "raw":
                mem_r, mem_c, mem_v, _ = _sort_dedup(mem_r, mem_c, mem_v,
                                                     combiner)
            start = jnp.searchsorted(mem_r, q, side="left").astype(jnp.int32)
            end = jnp.searchsorted(mem_r, q, side="right").astype(jnp.int32)
            idx = start[:, None] + iota[None, :]
            idxc = jnp.clip(idx, 0, mem_r.shape[0] - 1)
            seg_cols.append(mem_c[idxc])
            seg_vals.append(mem_v[idxc])
            seg_ok.append(idx < end[:, None])
            seg_age.append(n_levels + k0 + 1)
            cnts.append(end - start)
        # cross-run age-ordered combine, on-device
        cols_all = jnp.concatenate(seg_cols, axis=1)              # [Q, W]
        vals_all = jnp.concatenate(seg_vals, axis=1)
        ok_all = jnp.concatenate(seg_ok, axis=1)
        if has_filter:
            # residual column filter, on-device: sorted membership test
            # (filt pads with I32_MAX, which never equals a valid col)
            pos = jnp.clip(jnp.searchsorted(filt, cols_all), 0,
                           filt.shape[0] - 1)
            ok_all = ok_all & (filt[pos] == cols_all)
        ages = jnp.concatenate(
            [jnp.full((n_q, max_return), a, jnp.int32) for a in seg_age],
            axis=1)
        if pack:
            shift = (len(seg_age) + 1).bit_length()  # ages fit below shift
            key = jnp.where(ok_all, (cols_all << shift) + ages, I32_MAX)
            if cols_all.shape[1] <= 256:
                # packed keys are UNIQUE per row (cols unique within a run
                # segment, ages distinguish runs) — the merge_rank
                # rank+scatter combine beats XLA:CPU's scalar comparator
                # sort at these widths (N^2 branch-free compares, SIMD).
                key_s, val_s = merge_combine_rows(key, vals_all,
                                                  use_pallas=use_pallas,
                                                  interpret=INTERPRET)
            else:
                # widen retries can blow the candidate width up; the
                # quadratic compare loses to N log N there — fall back to
                # the packed single-key sort.
                key_s, val_s = jax.lax.sort((key, vals_all), dimension=1,
                                            num_keys=1)
            col_s = jnp.where(key_s == I32_MAX, I32_MAX, key_s >> shift)
        else:
            col_m = jnp.where(ok_all, cols_all, I32_MAX)
            col_s, _, val_s = jax.lax.sort(
                (col_m, ages, vals_all), dimension=1, num_keys=2)
        keep, out_v = jax.vmap(
            lambda r, v: _dedup_combine(r, jnp.zeros_like(r), v, combiner)
        )(col_s, val_s)
        cnt_max = jnp.max(jnp.stack([jnp.max(c) for c in cnts]))
        hits_vec = (jnp.stack(hits) if hits
                    else jnp.zeros((0,), jnp.bool_))
        return col_s, jnp.where(keep, out_v, 0.0), keep, cnt_max, hits_vec

    return jax.jit(fused)


@functools.lru_cache(maxsize=None)
def _fused_scan_fn(combiner: str, level_blocks: Tuple[int, ...], b0: int,
                   width: int, mem_mode: str, id_capacity: int,
                   use_pallas: bool, has_filter: bool = False):
    """Build THE single-dispatch range scan: a ``[lo, hi)`` row-range over
    one shard's resident leveled runs (deepest first), used L0 slots, and
    (optionally) memtable tail, answered inside one ``jax.jit``.

    Both endpoints are fence-bracketed exactly like the point path — rank
    ``lo`` and ``hi`` with ``side='left'`` (``hi`` exclusive), so each run
    contributes the contiguous candidate window ``[start, end)``. Under
    ``use_pallas`` the fence ranks go through the batched Pallas
    ``sorted_search`` kernel (the L0 stack in one launch, each level as a
    1-row batch). Per-run windows of static ``width`` are gathered into a
    ``[runs, width]`` candidate block; ``cnt_max`` > width signals the
    host to re-dispatch wider (batch-scanner semantics).

    The on-device merge-dedup sorts all candidates by ``(row, col, age)``
    and reduces equal-(row, col) groups with the combiner. Sort strategy
    by static key geometry (``kbits`` = id bits, ``abits`` = age bits):

    * ``2*kbits + abits <= 30``: ONE packed int32 key — XLA:CPU's fast
      single-key sort, same trick as the point path;
    * ``kbits + abits <= 31`` (the common 2^22-id config): (col, age)
      packs into one int32 and two STABLE single-key sorts (secondary
      then primary) implement the lexicographic order — still ~2 fast
      sorts instead of one ~10x-slower comparator sort;
    * else: a 3-key comparator sort (correctness fallback).

    With ``has_filter`` the dispatch takes an extra sorted int32 column
    id set (padded with I32_MAX) and masks candidates outside it before
    the merge-dedup — a range scan with a residual ``isin(cols)`` filter
    stays one dispatch with zero host post-filtering.

    Returns (rows[W], cols[W], vals[W], keep[W], cnt_max) with
    W = n_runs * width; kept entries are the combined triples sorted lex
    by (row, col).
    """
    from ..kvstore import _dedup_combine

    n_levels = len(level_blocks)

    def fused(lohi, levels, l0, mem, filt=None):
        iota = jnp.arange(width, dtype=jnp.int32)
        seg_r, seg_c, seg_v, seg_ok, seg_age, cnts = [], [], [], [], [], []

        def bracket(rows, f_ranks, block):
            cap = rows.shape[0]
            w = block + 1

            def one(qi, fi):
                base = jnp.clip(jnp.maximum(fi - 1, 0) * block, 0, cap - w)
                win = jax.lax.dynamic_slice(rows, (base,), (w,))
                return (base + jnp.searchsorted(win, qi, side="left")
                        ).astype(jnp.int32)

            return one(lohi[0], f_ranks[0]), one(lohi[1], f_ranks[1])

        def window(rows, cols, vals, start, end, age):
            idx = start + iota
            idxc = jnp.clip(idx, 0, rows.shape[0] - 1)
            seg_r.append(rows[idxc])
            seg_c.append(cols[idxc])
            seg_v.append(vals[idxc])
            seg_ok.append(idx < end)
            seg_age.append(age)
            cnts.append(end - start)

        # leveled runs, deepest (oldest) first — ages 1..L
        for i, (rows, cols, vals, fence, _bloom) in enumerate(levels):
            if use_pallas:
                flo, fhi = sorted_search_endpoints(fence[None], lohi,
                                                   interpret=INTERPRET)
                fr = jnp.stack([flo[0], fhi[0]])
            else:
                fr = jnp.searchsorted(fence, lohi, side="left"
                                      ).astype(jnp.int32)
            start, end = bracket(rows, fr, level_blocks[i])
            window(rows, cols, vals, start, end, i + 1)
        # the used L0 slots — ages L+1..L+K0
        l0_rows, l0_cols, l0_vals, l0_fence, _l0_bloom = l0
        k0 = l0_rows.shape[0]
        if k0:
            if use_pallas:
                flo0, fhi0 = sorted_search_endpoints(l0_fence, lohi,
                                                     interpret=INTERPRET)
                fr0 = jnp.stack([flo0, fhi0], axis=1)
            else:
                fr0 = jnp.stack([jnp.searchsorted(l0_fence[k], lohi,
                                                  side="left")
                                 .astype(jnp.int32) for k in range(k0)])
            for k in range(k0):
                start, end = bracket(l0_rows[k], fr0[k], b0)
                window(l0_rows[k], l0_cols[k], l0_vals[k], start, end,
                       n_levels + 1 + k)
        # the memtable tail (newest) — no fence metadata, direct ranks
        if mem_mode != "none":
            mem_r, mem_c, mem_v = mem
            if mem_mode == "raw":
                mem_r, mem_c, mem_v, _ = _sort_dedup(mem_r, mem_c, mem_v,
                                                     combiner)
            start = jnp.searchsorted(mem_r, lohi[0], side="left"
                                     ).astype(jnp.int32)
            end = jnp.searchsorted(mem_r, lohi[1], side="left"
                                   ).astype(jnp.int32)
            window(mem_r, mem_c, mem_v, start, end, n_levels + k0 + 1)
        # flat [W] candidate block, W = n_runs * width
        rows_all = jnp.concatenate(seg_r)
        cols_all = jnp.concatenate(seg_c)
        vals_all = jnp.concatenate(seg_v)
        ok_all = jnp.concatenate(seg_ok)
        if has_filter:
            # residual column filter, on-device: sorted membership test
            # (filt pads with I32_MAX, which never equals a valid col)
            pos = jnp.clip(jnp.searchsorted(filt, cols_all), 0,
                           filt.shape[0] - 1)
            ok_all = ok_all & (filt[pos] == cols_all)
        ages = jnp.concatenate([jnp.full((width,), a, jnp.int32)
                                for a in seg_age])
        abits = (len(seg_age) + 1).bit_length()
        kbits = max((id_capacity - 1).bit_length(), 1)
        if 2 * kbits + abits <= 30:
            key = jnp.where(ok_all, (rows_all << (kbits + abits))
                            + (cols_all << abits) + ages, I32_MAX)
            key_s, val_s = jax.lax.sort((key, vals_all), dimension=0,
                                        num_keys=1)
            pad = key_s == I32_MAX
            row_s = jnp.where(pad, I32_MAX, key_s >> (kbits + abits))
            col_s = jnp.where(pad, I32_MAX,
                              (key_s >> abits) & ((1 << kbits) - 1))
        elif kbits + abits <= 31:
            row_m = jnp.where(ok_all, rows_all, I32_MAX)
            key2 = jnp.where(ok_all, (cols_all << abits) + ages, I32_MAX)
            k2_s, row_1, val_1 = jax.lax.sort(
                (key2, row_m, vals_all), dimension=0, num_keys=1,
                is_stable=True)
            row_s, k2_f, val_s = jax.lax.sort(
                (row_1, k2_s, val_1), dimension=0, num_keys=1,
                is_stable=True)
            pad = row_s == I32_MAX
            col_s = jnp.where(pad, I32_MAX, k2_f >> abits)
        else:
            row_m = jnp.where(ok_all, rows_all, I32_MAX)
            col_m = jnp.where(ok_all, cols_all, I32_MAX)
            row_s, col_s, _, val_s = jax.lax.sort(
                (row_m, col_m, ages, vals_all), dimension=0, num_keys=3)
        keep, out_v = _dedup_combine(row_s, col_s, val_s, combiner)
        cnt_max = jnp.max(jnp.stack(cnts))
        return row_s, col_s, jnp.where(keep, out_v, 0.0), keep, cnt_max

    return jax.jit(fused)


def combine_triples(r: np.ndarray, c: np.ndarray, v: np.ndarray,
                    age: np.ndarray, combiner: str):
    """Host-side cross-run combine: sort candidates by (row, col, age) and
    reduce each key group per the combiner. Each source is already deduped
    (or, for the raw memtable, in append order with a constant age — the
    stable sort keeps append order, so 'last' still wins correctly)."""
    if len(r) == 0:
        z = np.zeros(0, np.int32)
        return z, z.copy(), np.zeros(0, np.float32)
    order = np.lexsort((age, c, r))
    r, c, v = r[order], c[order], v[order]
    new = np.ones(len(r), bool)
    new[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    starts = np.flatnonzero(new)
    if combiner == "last":
        ends = np.append(starts[1:], len(r)) - 1
        return r[starts], c[starts], v[ends]
    if combiner == "sum":
        vv = np.add.reduceat(v, starts)
    elif combiner == "min":
        vv = np.minimum.reduceat(v, starts)
    elif combiner == "max":
        vv = np.maximum.reduceat(v, starts)
    else:
        raise ValueError(f"unknown combiner {combiner!r}")
    return r[starts], c[starts], vv.astype(np.float32)


def _prep_mem(mem_host: Optional[Tuple], mem_sorted: bool):
    """Pad an unflushed memtable tail to a jit-stable bucket and pick the
    in-dispatch treatment: ``"sorted"`` = host pre-sorted/deduped mirror,
    ``"raw"`` = sort in-dispatch (stale-mirror/device path), ``"none"``."""
    mem_n = 0 if mem_host is None else len(mem_host[0])
    if not mem_n:
        return None, "none"
    mb = _bucket(mem_n)
    mr, mc, mv = mem_host
    if isinstance(mr, np.ndarray):
        pr = np.full(mb, I32_MAX, np.int32)
        pc = np.full(mb, I32_MAX, np.int32)
        pv = np.zeros(mb, np.float32)
        pr[:mem_n], pc[:mem_n], pv[:mem_n] = mr, mc, mv
        return (pr, pc, pv), ("sorted" if mem_sorted else "raw")
    # device arrays: pad lazily, stays async
    pad = mb - mem_n
    return (jnp.pad(mr, (0, pad), constant_values=I32_MAX),
            jnp.pad(mc, (0, pad), constant_values=I32_MAX),
            jnp.pad(mv, (0, pad))), "raw"


# counter schema shared by BOTH engines ("single" reports zeros where an
# op doesn't apply) so A/B stats line up in BENCH_ingest.json
STAT_KEYS = ("flushes", "major_compactions", "runs_probed", "runs_skipped",
             "fused_dispatches", "fused_widen_retries", "fused_tiles",
             "perrun_dispatches", "scan_dispatches", "scan_widen_retries")


# ------------------------------------------------------------------ engine
class LSMRuns:
    """The leveled run structure for S shards (no memtable — that stays in
    ``ShardedTable`` and is handed to ``flush_memtable``/read methods).

    ``bloom_bits_per_key`` / ``bloom_hashes`` size the per-run filters:
    scalars apply everywhere; sequences give one value per level (last
    entry repeats for deeper levels — ROADMAP "Bloom sizing": deep levels
    see most negative lookups, so size them denser). L0 runs always use
    the first entry (they are small and short-lived)."""

    def __init__(self, num_shards: int, capacity_per_shard: int,
                 mem_cap: int, combiner: str, use_pallas: bool = False,
                 l0_slots: int = 4, fanout: int = 4,
                 bloom_bits_per_key: Union[int, Sequence[int]] = BITS_PER_KEY,
                 bloom_hashes: Union[int, Sequence[int]] = NUM_HASHES,
                 id_capacity: int = 1 << 22, name: str = "lsm"):
        assert mem_cap >= 8, "LSM memtable too small to index"
        self.S = num_shards
        self.name = name
        self.cap = capacity_per_shard
        self.mem_cap = mem_cap
        self.combiner = combiner
        self.use_pallas = use_pallas
        self.id_capacity = id_capacity  # bounds col ids: fused key packing
        self.K0 = l0_slots
        self.fanout = fanout
        self.level_caps = plan_levels(capacity_per_shard, mem_cap, l0_slots,
                                      fanout)
        n_levels = len(self.level_caps)
        self.bloom_bits = _per_level(bloom_bits_per_key, n_levels)
        self.bloom_hashes = _per_level(bloom_hashes, n_levels)
        bad = [h for h in self.bloom_hashes if not 1 <= h <= MAX_HASHES]
        if bad:
            # _MULTS bounds the hash family; silently clamping would make
            # the manifest (and theoretical_fp_rate) lie about the filter
            raise ValueError(
                f"bloom_hashes {bad} outside [1, {MAX_HASHES}]")
        S, m, K0 = num_shards, mem_cap, l0_slots
        self._w0 = num_words(m, self.bloom_bits[0])
        self._h0 = self.bloom_hashes[0]
        self._b0 = fence_block(m)
        nblk0 = -(-m // self._b0)
        self.l0_rows = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_cols = jnp.full((S, K0, m), I32_MAX, jnp.int32)
        self.l0_vals = jnp.zeros((S, K0, m), jnp.float32)
        self.l0_bloom = jnp.zeros((S, K0, self._w0), jnp.uint32)
        self.l0_fence = jnp.full((S, K0, nblk0), I32_MAX, jnp.int32)
        self.l0_n = np.zeros((S, K0), np.int64)
        # host-side row ranges per run: skip runs without device roundtrips
        self.l0_min = np.full((S, K0), I32_MAX, np.int64)
        self.l0_max = np.full((S, K0), -1, np.int64)
        # per-SHARD used-slot counts: shards fill (and major-compact) their
        # own L0 independently — one hot shard no longer drags its peers
        # through a lockstep merge (ROADMAP "Leveled compaction tuning")
        self.l0_used = np.zeros((S,), np.int64)
        self.levels: List[dict] = []
        for i, cap in enumerate(self.level_caps):
            w = num_words(cap, self.bloom_bits[i])
            b = fence_block(cap)
            self.levels.append({
                "cap": cap, "words": w, "block": b,
                "bits": self.bloom_bits[i], "hashes": self.bloom_hashes[i],
                "rows": jnp.full((S, cap), I32_MAX, jnp.int32),
                "cols": jnp.full((S, cap), I32_MAX, jnp.int32),
                "vals": jnp.zeros((S, cap), jnp.float32),
                "bloom": jnp.zeros((S, w), jnp.uint32),
                "fence": jnp.full((S, -(-cap // b)), I32_MAX, jnp.int32),
                "n": np.zeros((S,), np.int64),
                "minr": np.full((S,), I32_MAX, np.int64),
                "maxr": np.full((S,), -1, np.int64),
            })
        # read/write-path observability: the old ad-hoc stats dict is now
        # registry counters labeled by table name (the `.stats` property
        # keeps the dict view). Series are reset at construction so a
        # fresh engine reads zeros, same as the dict did — two LIVE
        # engines sharing one table name share (and clobber) series,
        # which only test code does.
        self._reg = default_registry()
        self._trace = default_tracer()
        self._ctr = {k: self._reg.counter("lsm_" + k, table=name)
                     for k in STAT_KEYS}
        self._c_shard_flush = [
            self._reg.counter("lsm_shard_flushes", table=name, shard=s)
            for s in range(S)]
        self._c_shard_compact = [
            self._reg.counter("lsm_shard_compactions", table=name, shard=s)
            for s in range(S)]
        self._h_flush = self._reg.histogram("db_op_latency_s", table=name,
                                            op="flush")
        self._h_compact = self._reg.histogram("db_op_latency_s", table=name,
                                              op="major_compaction")
        # compile/retrace telemetry: one inc per fresh static signature of
        # the fused read builders (see _fused_query_compiled)
        self._c_retrace_q = self._reg.counter("lsm_retraces", table=name,
                                              op="query")
        self._c_retrace_s = self._reg.counter("lsm_retraces", table=name,
                                              op="scan")
        # write-amplification inputs: entries written into runs by flushes
        # and rewritten by compactions (vs db_ingest_entries)
        self._c_flush_entries = self._reg.counter("lsm_flush_entries",
                                                  table=name)
        self._c_compact_entries = self._reg.counter("lsm_compact_entries",
                                                    table=name)
        for inst in ([self._h_flush, self._h_compact]
                     + list(self._ctr.values())
                     + [self._c_retrace_q, self._c_retrace_s,
                        self._c_flush_entries, self._c_compact_entries]
                     + self._c_shard_flush + self._c_shard_compact):
            inst.reset()
        # per-run sliced views of the stacked arrays (slicing copies ~MBs
        # eagerly per query otherwise); invalidated on flush/compaction.
        # Fused-path entries key ("fused", s) and hold the level tuple +
        # L0 stack views handed to the single-dispatch query.
        self._view_cache: dict = {}

    @property
    def stats(self) -> dict:
        """Backward-compatible dict view of the registry counters (the old
        ad-hoc stats dict). Read-only: a fresh dict per access."""
        return {k: int(c.value) for k, c in self._ctr.items()}

    def warmup(self, mem_r, mem_c, mem_v) -> None:
        """Compile the flush + every compaction depth's graph by running
        them on the current (typically empty) state; results are discarded,
        so no state mutates. Keeps jit time out of benchmark windows."""
        rr, cc, vv, n, bb, ff, _, _ = _flush_fn(
            self.combiner, self._w0, self._b0, self._h0)(mem_r, mem_c, mem_v)
        _write_slot_fn()(self.l0_rows, self.l0_cols, self.l0_vals,
                         self.l0_bloom, self.l0_fence, rr, cc, vv, bb, ff,
                         jnp.zeros((self.S,), jnp.int32))
        for d, lv in enumerate(self.levels):
            lvls = tuple((self.levels[i]["rows"], self.levels[i]["cols"],
                          self.levels[i]["vals"]) for i in range(d, -1, -1))
            out = _compact_fn(self.combiner, self.use_pallas, lv["cap"],
                              lv["words"], lv["block"], lv["hashes"])(
                self.l0_rows, self.l0_cols, self.l0_vals, lvls)
            jax.block_until_ready(out)

    # ----------------------------------------------------------- write path
    def flush_memtable(self, mem_r, mem_c, mem_v) -> None:
        """Minor compaction: memtable -> one L0 run per shard, O(m log m).
        Shards whose OWN L0 is full (and that actually have data to flush)
        are major-compacted first — peers keep their L0 runs untouched.
        May raise OverflowError (capacity back-pressure, like the legacy
        engine)."""
        t0 = perf_counter()
        with self._trace.span("flush", table=self.name):
            self._flush_memtable(mem_r, mem_c, mem_v)
        self._h_flush.observe(perf_counter() - t0)

    def _flush_memtable(self, mem_r, mem_c, mem_v) -> None:
        rr, cc, vv, n, bb, ff, mn, mx = _flush_fn(
            self.combiner, self._w0, self._b0, self._h0)(mem_r, mem_c, mem_v)
        n_host = np.asarray(n).astype(np.int64)
        landing = n_host > 0          # shards receiving a non-empty run
        full = (self.l0_used >= self.K0) & landing
        if full.any():
            self.major_compact(mask=full)
        slot = self.l0_used.copy()    # per-shard next free slot (K0 = drop)
        (self.l0_rows, self.l0_cols, self.l0_vals, self.l0_bloom,
         self.l0_fence) = _write_slot_fn()(
            self.l0_rows, self.l0_cols, self.l0_vals, self.l0_bloom,
            self.l0_fence, rr, cc, vv, bb, ff,
            jnp.asarray(slot, jnp.int32))
        sidx = np.flatnonzero(landing)
        self.l0_n[sidx, slot[sidx]] = n_host[sidx]
        self.l0_min[sidx, slot[sidx]] = np.asarray(mn).astype(np.int64)[sidx]
        self.l0_max[sidx, slot[sidx]] = np.asarray(mx).astype(np.int64)[sidx]
        # all L0 slot views (and the fused stacked views, which embed the
        # L0 stack) alias the re-written arrays; drop them
        self._view_cache = {k: v for k, v in self._view_cache.items()
                            if k[0] not in ("l0", "fused")}
        self.l0_used = self.l0_used + landing.astype(np.int64)
        self._ctr["flushes"].inc()
        self._c_flush_entries.inc(int(n_host[sidx].sum()))
        for s in sidx:
            self._c_shard_flush[s].inc()
        full = self.l0_used >= self.K0
        if full.any():
            self.major_compact(mask=full)

    def _pick_depth(self, mask: np.ndarray) -> int:
        """Smallest level whose capacity bounds the (pre-dedup) merge size
        for every COMPACTING shard; the deepest level is the fallback."""
        bound = self.l0_n.sum(axis=1)  # [S]
        for d, lv in enumerate(self.levels):
            bound = bound + lv["n"]
            if int(bound[mask].max()) <= lv["cap"]:
                return d
        return len(self.levels) - 1

    def major_compact(self, mask: Optional[np.ndarray] = None) -> None:
        """Size-triggered major compaction: k-way merge the L0 runs and
        levels 1..d into level d (Pallas merge_rank under ``use_pallas``).

        ``mask`` selects WHICH shards compact (default: every shard with
        L0 data). The merge itself stays one vmapped dispatch over all S
        shards (static shapes); unmasked shards' merged output is simply
        discarded — their runs, counts, and L0 slots are untouched, so a
        single hot shard filling its L0 no longer forces a lockstep merge
        of every peer."""
        if mask is None:
            mask = self.l0_used > 0
        mask = np.asarray(mask, bool)
        if not mask.any():
            return
        t0 = perf_counter()
        with self._trace.span("major_compact", table=self.name,
                              shards=int(mask.sum())):
            self._major_compact(mask)
        self._h_compact.observe(perf_counter() - t0)

    def _major_compact(self, mask: np.ndarray) -> None:
        d = self._pick_depth(mask)
        target = self.levels[d]
        # deepest first = oldest first (kway_merge contract)
        lvls = tuple((self.levels[i]["rows"], self.levels[i]["cols"],
                      self.levels[i]["vals"]) for i in range(d, -1, -1))
        rr, cc, vv, n, bb, ff, mn, mx = _compact_fn(
            self.combiner, self.use_pallas, target["cap"], target["words"],
            target["block"], target["hashes"])(
            self.l0_rows, self.l0_cols, self.l0_vals, lvls)
        n_host = np.asarray(n)
        if d == len(self.levels) - 1 and int(n_host[mask].max()) > self.cap:
            raise OverflowError(
                f"LSM shard overflow: {int(n_host[mask].max())} > {self.cap}")
        m_dev = jnp.asarray(mask)

        def sel(new, old):
            m = m_dev.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        target.update(
            rows=sel(rr, target["rows"]), cols=sel(cc, target["cols"]),
            vals=sel(vv, target["vals"]), bloom=sel(bb, target["bloom"]),
            fence=sel(ff, target["fence"]),
            n=np.where(mask, n_host, target["n"]).astype(np.int64),
            minr=np.where(mask, np.asarray(mn),
                          target["minr"]).astype(np.int64),
            maxr=np.where(mask, np.asarray(mx),
                          target["maxr"]).astype(np.int64))
        # clear L0 + the shallower levels for the compacted shards ONLY
        m3 = m_dev[:, None, None]
        self.l0_rows = jnp.where(m3, jnp.int32(I32_MAX), self.l0_rows)
        self.l0_cols = jnp.where(m3, jnp.int32(I32_MAX), self.l0_cols)
        self.l0_vals = jnp.where(m3, jnp.float32(0.0), self.l0_vals)
        self.l0_bloom = jnp.where(m3, jnp.uint32(0), self.l0_bloom)
        self.l0_fence = jnp.where(m3, jnp.int32(I32_MAX), self.l0_fence)
        self.l0_n[mask] = 0
        self.l0_min[mask] = I32_MAX
        self.l0_max[mask] = -1
        self.l0_used[mask] = 0
        m2 = m_dev[:, None]
        for i in range(d):
            lv = self.levels[i]
            lv["rows"] = jnp.where(m2, jnp.int32(I32_MAX), lv["rows"])
            lv["cols"] = jnp.where(m2, jnp.int32(I32_MAX), lv["cols"])
            lv["vals"] = jnp.where(m2, jnp.float32(0.0), lv["vals"])
            lv["bloom"] = jnp.where(m2, jnp.uint32(0), lv["bloom"])
            lv["fence"] = jnp.where(m2, jnp.int32(I32_MAX), lv["fence"])
            lv["n"][mask] = 0
            lv["minr"][mask] = I32_MAX
            lv["maxr"][mask] = -1
        self._view_cache.clear()
        self._ctr["major_compactions"].inc()
        self._c_compact_entries.inc(int(n_host[mask].sum()))
        for s in np.flatnonzero(mask):
            self._c_shard_compact[s].inc()

    # ------------------------------------------------------------ read path
    def resident_runs(self, s: int) -> int:
        """How many non-empty runs shard ``s`` holds (levels + L0)."""
        n = sum(1 for lv in self.levels if lv["n"][s])
        n += sum(1 for k in range(int(self.l0_used[s])) if self.l0_n[s, k])
        return n

    # ------------------------------------------------------ tablet support
    def clear_shard(self, s: int) -> None:
        """Drop EVERY resident run of one shard — L0 slots and all
        levels, including the deepest. Tablet migration uses this: the
        caller has already scanned the shard's combined triples and will
        re-insert them under the new tablet map, so the old physical
        placement must vanish first (otherwise moved entries would be
        served from both shards)."""
        mask = np.zeros((self.S,), bool)
        mask[s] = True
        m_dev = jnp.asarray(mask)
        m3 = m_dev[:, None, None]
        self.l0_rows = jnp.where(m3, jnp.int32(I32_MAX), self.l0_rows)
        self.l0_cols = jnp.where(m3, jnp.int32(I32_MAX), self.l0_cols)
        self.l0_vals = jnp.where(m3, jnp.float32(0.0), self.l0_vals)
        self.l0_bloom = jnp.where(m3, jnp.uint32(0), self.l0_bloom)
        self.l0_fence = jnp.where(m3, jnp.int32(I32_MAX), self.l0_fence)
        self.l0_n[mask] = 0
        self.l0_min[mask] = I32_MAX
        self.l0_max[mask] = -1
        self.l0_used[mask] = 0
        m2 = m_dev[:, None]
        for lv in self.levels:
            lv["rows"] = jnp.where(m2, jnp.int32(I32_MAX), lv["rows"])
            lv["cols"] = jnp.where(m2, jnp.int32(I32_MAX), lv["cols"])
            lv["vals"] = jnp.where(m2, jnp.float32(0.0), lv["vals"])
            lv["bloom"] = jnp.where(m2, jnp.uint32(0), lv["bloom"])
            lv["fence"] = jnp.where(m2, jnp.int32(I32_MAX), lv["fence"])
            lv["n"][mask] = 0
            lv["minr"][mask] = I32_MAX
            lv["maxr"][mask] = -1
        self._view_cache.clear()

    def fence_keys(self, s: int, lo: int, hi: int) -> np.ndarray:
        """Sorted host view of shard ``s``'s resident fence keys inside
        ``[lo, hi)``. Fences sample each sorted run at fixed block
        stride, so their distribution tracks the shard's key
        distribution without scanning any run."""
        keys = []
        for lv in self.levels:
            if lv["n"][s] and lv["minr"][s] < hi and lv["maxr"][s] >= lo:
                keys.append(np.asarray(lv["fence"][s]))
        for k in range(int(self.l0_used[s])):
            if (self.l0_n[s, k] and self.l0_min[s, k] < hi
                    and self.l0_max[s, k] >= lo):
                keys.append(np.asarray(self.l0_fence[s, k]))
        if not keys:
            return np.zeros(0, np.int64)
        cat = np.concatenate(keys).astype(np.int64)
        cat = cat[(cat >= lo) & (cat < hi) & (cat != I32_MAX)]
        cat.sort()
        return cat

    def fence_median(self, s: int, lo: int, hi: int) -> int:
        """Median resident fence key of shard ``s`` within ``[lo, hi)``
        — the tablet split point: an approximate median KEY of the
        shard's data in the range, for free. Falls back to the range
        midpoint when no fence lands inside; the result is always
        strictly interior to ``(lo, hi)`` (callers ensure width > 1)."""
        ks = self.fence_keys(s, lo, hi)
        med = int(np.median(ks)) if len(ks) else (int(lo) + int(hi)) // 2
        return int(min(max(med, int(lo) + 1), int(hi) - 1))

    # --------------------------------------------------------- health view
    def refresh_health_gauges(self, bloom_probes: int = 0) -> None:
        """Derive the engine health gauges from current state: resident
        runs + compaction debt per shard, read amplification (runs probed
        per read dispatch) and write amplification (entries written by
        flush/compaction per entry ingested) per table. All inputs are
        host-side mirrors/counters — no device sync. ``bloom_probes > 0``
        additionally measures the observed bloom fp rate by probing each
        resident run's filter with keys provably outside its row range
        (costs one tiny dispatch per resident run)."""
        reg = self._reg
        for s in range(self.S):
            reg.gauge("lsm_resident_runs", table=self.name, shard=s).set(
                self.resident_runs(s))
            u = int(self.l0_used[s])
            reg.gauge("lsm_compaction_debt_entries", table=self.name,
                      shard=s).set(int(self.l0_n[s, :u].sum()))
        c = self._ctr
        reads = int(c["fused_dispatches"].value
                    + c["perrun_dispatches"].value)
        probed = int(c["runs_probed"].value)
        reg.gauge("lsm_read_amplification", table=self.name).set(
            probed / reads if reads else 0.0)
        ingested = sum(int(x.value) for x in
                       reg.series("db_ingest_entries", table=self.name))
        written = int(self._c_flush_entries.value
                      + self._c_compact_entries.value)
        reg.gauge("lsm_write_amplification", table=self.name).set(
            written / ingested if ingested else 0.0)
        if bloom_probes:
            obs_fp, theo_fp = self._bloom_fp_probe(bloom_probes)
            reg.gauge("lsm_bloom_fp_observed", table=self.name).set(obs_fp)
            reg.gauge("lsm_bloom_fp_theoretical",
                      table=self.name).set(theo_fp)

    def _bloom_fp_probe(self, probes: int):
        """(observed, theoretical) bloom fp rate over the resident runs.

        Probe keys are sampled outside a run's host-tracked [minr, maxr]
        row range, so the run provably does not contain them — any filter
        hit is a certain false positive. The theoretical rate is the
        classic bound, probe-count weighted across runs."""
        rng = np.random.default_rng(0xB100F)
        tot_probes = tot_fp = 0
        theo_w = 0.0
        for s in range(self.S):
            runs = [(lv["bloom"][s], lv["hashes"], lv["words"],
                     int(lv["n"][s]), int(lv["minr"][s]), int(lv["maxr"][s]))
                    for lv in self.levels if lv["n"][s]]
            runs += [(self.l0_bloom[s, k], self._h0, self._w0,
                      int(self.l0_n[s, k]), int(self.l0_min[s, k]),
                      int(self.l0_max[s, k]))
                     for k in range(int(self.l0_used[s]))
                     if self.l0_n[s, k]]
            for words, n_hashes, n_words, n_keys, minr, maxr in runs:
                cand = rng.integers(0, self.id_capacity, 4 * probes)
                cand = cand[(cand < minr) | (cand > maxr)][:probes]
                if len(cand) < probes:
                    continue  # run spans ~the whole id space: no negatives
                hits = bloom_maybe_contains(
                    jnp.asarray(words), jnp.asarray(cand, jnp.int32),
                    n_hashes=n_hashes)
                tot_fp += int(np.asarray(hits).sum())
                tot_probes += probes
                theo_w += probes * theoretical_fp_rate(n_keys, n_words,
                                                       n_hashes)
        if not tot_probes:
            return 0.0, 0.0
        return tot_fp / tot_probes, theo_w / tot_probes

    def _iter_runs_oldest_first(self, s: int):
        """Yield (rows, cols, vals, fence, bloom, n, block, minr, maxr,
        hashes) per-run views of shard ``s``, oldest (deepest level) to
        newest (latest L0 slot)."""
        for i in range(len(self.levels) - 1, -1, -1):
            lv = self.levels[i]
            if lv["n"][s]:
                key = ("lvl", i, s)
                view = self._view_cache.get(key)
                if view is None:
                    view = (lv["rows"][s], lv["cols"][s], lv["vals"][s],
                            lv["fence"][s], lv["bloom"][s])
                    self._view_cache[key] = view
                yield view + (int(lv["n"][s]), lv["block"],
                              int(lv["minr"][s]), int(lv["maxr"][s]),
                              lv["hashes"])
        for k in range(int(self.l0_used[s])):
            if self.l0_n[s, k]:
                key = ("l0", k, s)
                view = self._view_cache.get(key)
                if view is None:
                    view = (self.l0_rows[s, k], self.l0_cols[s, k],
                            self.l0_vals[s, k], self.l0_fence[s, k],
                            self.l0_bloom[s, k])
                    self._view_cache[key] = view
                yield view + (int(self.l0_n[s, k]), self._b0,
                              int(self.l0_min[s, k]), int(self.l0_max[s, k]),
                              self._h0)

    def _fused_views(self, s: int):
        """Per-shard stacked views for the fused dispatch: the RESIDENT
        leveled runs (deepest first, with their static fence-block/hash
        meta) plus the L0 stack sliced to the used slots. Restricting the
        dispatch to resident runs is what lets it beat the per-run path —
        probing an empty 256k-capacity level costs real gather work.
        Residency only changes on flush/compaction, which is exactly when
        this cache invalidates, so the slicing cost is amortized across
        every query in between (no per-query re-bucketing)."""
        key = ("fused", s)
        view = self._view_cache.get(key)
        if view is None:
            live = [i for i in range(len(self.levels) - 1, -1, -1)
                    if self.levels[i]["n"][s]]
            levels = tuple(
                (self.levels[i]["rows"][s], self.levels[i]["cols"][s],
                 self.levels[i]["vals"][s], self.levels[i]["fence"][s],
                 self.levels[i]["bloom"][s])
                for i in live)
            blocks = tuple(self.levels[i]["block"] for i in live)
            hashes = tuple(self.levels[i]["hashes"] for i in live)
            u = int(self.l0_used[s])
            l0 = (self.l0_rows[s, :u], self.l0_cols[s, :u],
                  self.l0_vals[s, :u], self.l0_fence[s, :u],
                  self.l0_bloom[s, :u])
            view = (levels, blocks, hashes, tuple(live), l0)
            self._view_cache[key] = view
        return view

    # -- compile/retrace telemetry ----------------------------------------
    # The fused read builders are lru_cache'd on their STATIC signature, so
    # a builder cache miss == one fresh XLA trace+compile. Counting misses
    # turns the "no batch size ever retraces" serving invariant into a
    # registry-asserted guarantee: after warm_reads() the lsm_retraces
    # counter must stay flat across any batch-size sweep.
    def _fused_query_compiled(self, *key):
        misses0 = _fused_query_fn.cache_info().misses
        fn = _fused_query_fn(*key)
        ci = _fused_query_fn.cache_info()
        if ci.misses != misses0:
            self._c_retrace_q.inc()
            self._reg.gauge("lsm_compiled_shapes", op="query").set(
                ci.currsize)
        return fn

    def _fused_scan_compiled(self, *key):
        misses0 = _fused_scan_fn.cache_info().misses
        fn = _fused_scan_fn(*key)
        ci = _fused_scan_fn.cache_info()
        if ci.misses != misses0:
            self._c_retrace_s.inc()
            self._reg.gauge("lsm_compiled_shapes", op="scan").set(
                ci.currsize)
        return fn

    def query_shard_fused(self, s: int, q: np.ndarray,
                          mem_host: Optional[Tuple] = None,
                          max_return: int = 256,
                          mem_sorted: bool = False,
                          q_tile: Optional[int] = None,
                          col_filter: Optional[np.ndarray] = None):
        """Point row queries for one shard, fused: each dispatch searches
        the resident leveled runs, the used L0 slots, and the memtable
        tail and age-order combines on-device. ``q`` must be sorted unique
        int32 (the ``ShardedTable`` driver guarantees it); ``mem_host`` is
        the shard's unflushed tail as (rows, cols, vals) arrays — numpy
        (host mirror; pass ``mem_sorted=True`` if already
        (row, col)-sorted and combiner-deduped) or device slices
        (stale-mirror SPMD path). NO flush happens.

        When ``q_tile`` is set the read path serves every batch size from
        exactly TWO static shapes: tiny point reads (n_q <= 8) use the
        small bucket, and everything else pads UP to the ``q_tile`` tile —
        batches larger than the tile split into ceil(n_q / tile)
        dispatches of that one shape, each independently widen-retryable.
        One jit cache entry therefore covers every large batch size the
        caller ever sends (a fresh size never retraces — the legacy
        engine, whose query shape follows the batch, recompiles per novel
        size). Each run's probe is block bloom-gated inside the dispatch,
        so a tile whose keys all miss a run's filter skips that run's
        search entirely. Tiles are contiguous slices of the sorted ``q``,
        so concatenating per-tile results preserves global row order.
        ``q_tile=None`` keeps the legacy bucket-by-batch-size shapes.

        ``col_filter`` (optional int32 id set) pushes the residual
        column ``isin`` of a row-driven read into the dispatch as an
        on-device sorted-membership mask — no host post-filter."""
        n_q = len(q)
        filt_dev = None
        has_filter = col_filter is not None
        if has_filter:
            cf = np.unique(np.asarray(col_filter, np.int32))
            if len(cf) == 0:  # empty filter: nothing can match
                z = np.zeros(0, np.int32)
                return z, z.copy(), np.zeros(0, np.float32)
            cf_pad = np.full(_bucket(len(cf)), I32_MAX, np.int32)
            cf_pad[:len(cf)] = cf
            filt_dev = jnp.asarray(cf_pad)
        mem, mem_mode = _prep_mem(mem_host, mem_sorted)
        levels, blocks, hashes, live, l0 = self._fused_views(s)
        n_runs = len(levels) + int(l0[0].shape[0]) + (mem_mode != "none")
        # single-int32 (col, age) key packing needs col * age_pad headroom
        pack = self.id_capacity <= (1 << 24) and n_runs + 2 < 64
        # small initial per-run return width: the combine cost scales with
        # Qtile * (runs * width)^2, and point reads rarely exceed a few
        # entries per run — cnt_max triggers the widen retry when they do
        r_ret = min(4, _bucket(max_return))
        tile = (_bucket(n_q) if q_tile is None or n_q <= 8
                else _bucket(q_tile))
        n_tiles = max(1, -(-n_q // tile))
        if n_tiles > 1:
            self._ctr["fused_tiles"].inc(n_tiles)
        fn = self._fused_query_compiled(self.combiner, blocks, hashes,
                                        self._b0, self._h0, r_ret,
                                        mem_mode, pack, self.use_pallas,
                                        has_filter)
        tr = self._trace
        out_r, out_c, out_v = [], [], []
        hit_any = None
        with tr.span("query.fused", table=self.name, shard=s, n_q=n_q,
                     tiles=n_tiles):
            for t in range(n_tiles):
                q_blk = q[t * tile:(t + 1) * tile]
                nb = len(q_blk)
                q_pad = np.full(tile, -1, np.int32)  # -1: matches nothing
                q_pad[:nb] = q_blk
                self._ctr["fused_dispatches"].inc()
                with tr.span("dispatch", tile=t):
                    out = fn(q_pad, levels, l0, mem, filt_dev)
                with tr.span("host_sync"):
                    cols_s, vals_s, keep, cnt_max, hits = \
                        tuple(np.asarray(x) for x in out)
                if int(cnt_max) > r_ret:  # widen + retry (scanner)
                    self._ctr["fused_widen_retries"].inc()
                    self._ctr["fused_dispatches"].inc()
                    with tr.span("widen_retry", width=int(cnt_max)):
                        wfn = self._fused_query_compiled(
                            self.combiner, blocks, hashes, self._b0,
                            self._h0, _bucket(int(cnt_max)), mem_mode,
                            pack, self.use_pallas, has_filter)
                        out = wfn(q_pad, levels, l0, mem, filt_dev)
                        cols_s, vals_s, keep, cnt_max, hits = \
                            tuple(np.asarray(x) for x in out)
                qi, ki = np.nonzero(keep[:nb])
                out_r.append(q_blk[qi])
                out_c.append(cols_s[:nb][qi, ki])
                out_v.append(vals_s[:nb][qi, ki])
                hit_any = hits if hit_any is None else (hit_any | hits)
        # observability: a run counts as probed if ANY tile's query block
        # hit its bloom; hits = [resident levels deepest-first, used slots]
        probed, skipped = self._ctr["runs_probed"], self._ctr["runs_skipped"]
        for i in range(len(live)):
            (probed if hit_any[i] else skipped).inc()
        for k in range(int(self.l0_used[s])):
            if self.l0_n[s, k]:
                (probed if hit_any[len(live) + k] else skipped).inc()
        return (np.concatenate(out_r).astype(np.int32),
                np.concatenate(out_c).astype(np.int32),
                np.concatenate(out_v).astype(np.float32))

    def scan_shard_fused(self, s: int, lo: int, hi: int,
                         mem_host: Optional[Tuple] = None,
                         width: int = 64, mem_sorted: bool = False,
                         col_filter: Optional[np.ndarray] = None):
        """Row-range scan ``[lo, hi)`` of one shard in ONE jitted dispatch
        + ONE host sync: every resident leveled run, used L0 slot, and the
        memtable tail is fence-bracketed at both endpoints and the
        candidate windows are merged-deduped on-device (the read-path
        analogue of the fused point query — no per-run dispatches, no
        id-list point expansion). ``width`` is the initial per-run window;
        a run whose range slice overflows it triggers ONE widen retry at
        the next pow2 ≥ the true max slice. Returns combined
        (rows, cols, vals) sorted lex by (row, col). NO flush happens.

        ``col_filter`` (optional int32 id set) masks columns outside the
        set on-device before the merge-dedup (residual ``isin``)."""
        lo, hi = int(lo), int(hi)
        empty = (np.zeros(0, np.int32), np.zeros(0, np.int32),
                 np.zeros(0, np.float32))
        filt_dev = None
        has_filter = col_filter is not None
        if has_filter:
            cf = np.unique(np.asarray(col_filter, np.int32))
            if len(cf) == 0:  # empty filter: nothing can match
                return empty
            cf_pad = np.full(_bucket(len(cf)), I32_MAX, np.int32)
            cf_pad[:len(cf)] = cf
            filt_dev = jnp.asarray(cf_pad)
        mem, mem_mode = _prep_mem(mem_host, mem_sorted)
        if hi <= lo:
            return empty
        # host run-range metadata: skip the dispatch entirely when no
        # resident run (and no memtable tail) intersects [lo, hi)
        inter = mem_mode != "none"
        if not inter:
            for lv in self.levels:
                if lv["n"][s] and lv["minr"][s] < hi and lv["maxr"][s] >= lo:
                    inter = True
                    break
        if not inter:
            for k in range(int(self.l0_used[s])):
                if (self.l0_n[s, k] and self.l0_min[s, k] < hi
                        and self.l0_max[s, k] >= lo):
                    inter = True
                    break
        if not inter:
            return empty
        levels, blocks, hashes, live, l0 = self._fused_views(s)
        if not levels and not int(l0[0].shape[0]) and mem_mode == "none":
            return empty
        lohi = jnp.asarray(np.asarray([lo, hi], np.int32))
        w = _bucket(width, lo=16)
        fn = self._fused_scan_compiled(self.combiner, blocks, self._b0, w,
                                       mem_mode, self.id_capacity,
                                       self.use_pallas, has_filter)
        tr = self._trace
        self._ctr["scan_dispatches"].inc()
        with tr.span("scan.fused", table=self.name, shard=s, lo=lo, hi=hi):
            with tr.span("dispatch"):
                out = fn(lohi, levels, l0, mem, filt_dev)
            with tr.span("host_sync"):
                rows_s, cols_s, vals_s, keep, cnt_max = \
                    tuple(np.asarray(x) for x in out)
            if int(cnt_max) > w:  # widen + retry (batch-scanner semantics)
                self._ctr["scan_widen_retries"].inc()
                self._ctr["scan_dispatches"].inc()
                with tr.span("widen_retry", width=int(cnt_max)):
                    fn = self._fused_scan_compiled(
                        self.combiner, blocks, self._b0,
                        _bucket(int(cnt_max)), mem_mode,
                        self.id_capacity, self.use_pallas, has_filter)
                    out = fn(lohi, levels, l0, mem, filt_dev)
                    rows_s, cols_s, vals_s, keep, _ = \
                        tuple(np.asarray(x) for x in out)
        ki = np.flatnonzero(keep)
        return (rows_s[ki].astype(np.int32), cols_s[ki].astype(np.int32),
                vals_s[ki].astype(np.float32))

    def query_shard(self, s: int, q: np.ndarray, mem_r, mem_c, mem_v,
                    mem_n: int, max_return: int,
                    mem_host: Optional[Tuple[np.ndarray, ...]] = None):
        """Per-run baseline read path: probe runs oldest→newest plus the
        memtable tail, one bloom-gated launch per resident run, combine
        across sources on the host. NO flush happens.

        Two-phase: launch the bloom-gated query of every candidate run
        asynchronously, then sync once and harvest — latency is one device
        round-trip but still N dispatches; ``query_shard_fused`` collapses
        those into one. ``mem_host`` is an optional host mirror of the
        shard's memtable (avoids pulling the device buffer)."""
        q_dev = jnp.asarray(q)
        q_sorted = np.sort(q)
        launched = []
        age = 0
        for rows, cols, vals, fence, bloom, n, block, minr, maxr, hashes in \
                self._iter_runs_oldest_first(s):
            age += 1
            if q_sorted[-1] < minr or q_sorted[0] > maxr:
                self._ctr["runs_skipped"].inc()
                continue
            self._ctr["perrun_dispatches"].inc()
            out = run_query_gated(rows, cols, vals, fence, bloom, q_dev,
                                  max_return, block, hashes)
            launched.append((age, (rows, cols, vals, fence, block), out))
        cand_r, cand_c, cand_v, cand_a = [], [], [], []
        for age_i, run, (any_hit, cols_o, vals_o, ok, cnt) in launched:
            if not bool(any_hit):  # bloom says absent — search was skipped
                self._ctr["runs_skipped"].inc()
                continue
            self._ctr["runs_probed"].inc()
            cnt = np.asarray(cnt)
            if cnt.max(initial=0) > max_return:  # widen + retry (scanner)
                rows, cols, vals, fence, block = run
                self._ctr["perrun_dispatches"].inc()
                cols_o, vals_o, ok, cnt = run_query_rows(
                    rows, cols, vals, fence, q_dev, int(cnt.max()), block)
            ok = np.asarray(ok)
            cols_o, vals_o = np.asarray(cols_o), np.asarray(vals_o)
            qi, ki = np.nonzero(ok)
            cand_r.append(q[qi]); cand_c.append(cols_o[qi, ki])
            cand_v.append(vals_o[qi, ki])
            cand_a.append(np.full(len(qi), age_i, np.int32))
        if mem_n:
            if mem_host is not None:
                mr, mc, mv = mem_host
            else:
                mr = np.asarray(mem_r[:mem_n])
                mc = np.asarray(mem_c[:mem_n])
                mv = np.asarray(mem_v[:mem_n])
            mask = np.isin(mr, q)
            if mask.any():
                cand_r.append(mr[mask])
                cand_c.append(mc[mask])
                cand_v.append(mv[mask])
                cand_a.append(np.full(int(mask.sum()), age + 1, np.int32))
        if not cand_r:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        return combine_triples(np.concatenate(cand_r).astype(np.int32),
                               np.concatenate(cand_c).astype(np.int32),
                               np.concatenate(cand_v).astype(np.float32),
                               np.concatenate(cand_a), self.combiner)

    def scan_shard(self, s: int, mem_r, mem_c, mem_v, mem_n: int,
                   mem_host: Optional[Tuple[np.ndarray, ...]] = None):
        """All (row, col, val) of one shard, combined across runs + memtable,
        sorted lex by (row, col). NO flush happens."""
        cand = []
        age = 0
        for rows, cols, vals, fence, bloom, n, block, minr, maxr, hashes in \
                self._iter_runs_oldest_first(s):
            age += 1
            cand.append((np.asarray(rows[:n]), np.asarray(cols[:n]),
                         np.asarray(vals[:n]),
                         np.full(n, age, np.int32)))
        if mem_n:
            if mem_host is not None:
                mr, mc, mv = mem_host
            else:
                mr = np.asarray(mem_r[:mem_n])
                mc = np.asarray(mem_c[:mem_n])
                mv = np.asarray(mem_v[:mem_n])
            cand.append((mr, mc, mv, np.full(len(mr), age + 1, np.int32)))
        if not cand:
            z = np.zeros(0, np.int32)
            return z, z.copy(), np.zeros(0, np.float32)
        r = np.concatenate([x[0] for x in cand]).astype(np.int32)
        c = np.concatenate([x[1] for x in cand]).astype(np.int32)
        v = np.concatenate([x[2] for x in cand]).astype(np.float32)
        a = np.concatenate([x[3] for x in cand])
        return combine_triples(r, c, v, a, self.combiner)

    # --------------------------------------------------------- persistence
    def state_arrays(self) -> dict:
        """Flat name -> np.ndarray map of all run state (for snapshots)."""
        out = {
            "l0_rows": np.asarray(self.l0_rows),
            "l0_cols": np.asarray(self.l0_cols),
            "l0_vals": np.asarray(self.l0_vals),
            "l0_n": self.l0_n.copy(),
            "l0_used": self.l0_used.copy(),
        }
        for i, lv in enumerate(self.levels):
            out[f"lvl{i}_rows"] = np.asarray(lv["rows"])
            out[f"lvl{i}_cols"] = np.asarray(lv["cols"])
            out[f"lvl{i}_vals"] = np.asarray(lv["vals"])
            out[f"lvl{i}_n"] = lv["n"].copy()
        return out

    def load_state(self, arrs: dict) -> None:
        """Restore from ``state_arrays`` output; blooms and fences are
        derived data and get rebuilt (cheaper than persisting them)."""
        self._view_cache.clear()
        l0_rows_np = np.asarray(arrs["l0_rows"])
        self.l0_rows = jnp.asarray(l0_rows_np)
        self.l0_cols = jnp.asarray(arrs["l0_cols"])
        self.l0_vals = jnp.asarray(arrs["l0_vals"])
        self.l0_n = np.asarray(arrs["l0_n"]).astype(np.int64)
        lu = np.asarray(arrs["l0_used"])
        # pre-PR-3 snapshots persisted ONE scalar (lockstep slot counter);
        # broadcast it — every shard then reports the same used count, and
        # empty slots below it stay inert I32_MAX padding as before
        self.l0_used = (np.full((self.S,), int(lu), np.int64)
                        if lu.ndim == 0 else lu.astype(np.int64))
        self.l0_bloom = _bloom_rebuild_fn(self._w0, self._h0,
                                          nested=True)(self.l0_rows)
        self.l0_fence = self.l0_rows[:, :, ::self._b0]
        self.l0_min = l0_rows_np[:, :, 0].astype(np.int64)
        last = np.maximum(self.l0_n - 1, 0)
        self.l0_max = np.take_along_axis(
            l0_rows_np, last[:, :, None].astype(np.int64), axis=2
        )[:, :, 0].astype(np.int64)
        for i, lv in enumerate(self.levels):
            rows_np = np.asarray(arrs[f"lvl{i}_rows"])
            lv["rows"] = jnp.asarray(rows_np)
            lv["cols"] = jnp.asarray(arrs[f"lvl{i}_cols"])
            lv["vals"] = jnp.asarray(arrs[f"lvl{i}_vals"])
            lv["n"] = np.asarray(arrs[f"lvl{i}_n"]).astype(np.int64)
            lv["bloom"] = _bloom_rebuild_fn(lv["words"], lv["hashes"],
                                            nested=False)(lv["rows"])
            lv["fence"] = lv["rows"][:, ::lv["block"]]
            lv["minr"] = rows_np[:, 0].astype(np.int64)
            last = np.maximum(lv["n"] - 1, 0).astype(np.int64)
            lv["maxr"] = rows_np[np.arange(self.S), last].astype(np.int64)
