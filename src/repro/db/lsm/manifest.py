"""Snapshot manifest + crash recovery for the LSM engine.

Durability contract (Accumulo-shaped):

  * every ingest batch is appended to the WAL before it touches the
    memtable (``ShardedTable.insert`` with ``wal_dir`` set);
  * ``checkpoint()`` minor-compacts the memtable, then atomically writes a
    snapshot of all sorted runs plus ``MANIFEST.json`` recording the WAL
    byte offset the snapshot covers;
  * ``recover(dir)`` rebuilds the table: construct from the manifest's
    config, load the snapshot runs, replay only the WAL suffix past the
    recorded offset. A torn WAL tail (simulated crash) is discarded by the
    WAL's CRC framing.

This module persists the encoded (row_id, col_id, value) store only; the
string dictionaries live one layer up — ``db.connector`` journals them
(checkpoint snapshot + append log next to this manifest) and
``db.connector.recover_connector`` combines both layers to restore
string-keyed queries.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

MANIFEST = "MANIFEST.json"
SNAPSHOT = "snapshot.npz"
WAL_FILE = "wal.log"

# transpose-sibling state arrays share the snapshot under this prefix —
# one atomic npz replace covers BOTH tables of a pair
_T_PREFIX = "t_"


def wal_path(dirpath: str) -> str:
    return os.path.join(dirpath, WAL_FILE)


def write_snapshot(table, dirpath: str) -> str:
    """Persist ``table``'s run state + manifest; returns the manifest path.

    Caller must have flushed the memtable first (``Table.checkpoint`` does);
    the manifest's ``wal_offset`` then covers everything in the snapshot, so
    recovery replays exactly the post-snapshot suffix.
    """
    import dataclasses

    os.makedirs(dirpath, exist_ok=True)
    runs = table._runs  # LSM engine only
    state = dict(runs.state_arrays())
    if table.t_store is not None:  # pair: sibling rides in the same npz
        for k, v in table.t_store._runs.state_arrays().items():
            state[_T_PREFIX + k] = v
    snap_tmp = os.path.join(dirpath, SNAPSHOT + ".tmp")
    with open(snap_tmp, "wb") as f:
        np.savez(f, **state)
        f.flush()
        os.fsync(f.fileno())
    os.replace(snap_tmp, os.path.join(dirpath, SNAPSHOT))
    # the StoreConfig round-trips verbatim (recover() rebuilds from it via
    # StoreConfig.from_manifest — no hand-listed field relay); per-table
    # extras (combiner, resolved mem_cap, bloom sizing) ride alongside
    config = dataclasses.asdict(table.config)
    config.update({
        "combiner": table.combiner,
        "mem_cap": table.mem_cap,
        "bloom_bits_per_key": list(runs.bloom_bits),
        "bloom_hashes": list(runs.bloom_hashes),
    })
    man = {
        # format 3 = format 2 + the "tablets" key (dynamic tablet map);
        # static tables keep writing format 2 so older readers still work
        "format": 3 if table.tablet_map is not None else 2,
        "name": table.name,
        "config": config,
        "snapshot": SNAPSHOT,
        "wal": WAL_FILE,
        "wal_offset": table._wal.tell() if table._wal else 0,
    }
    if table.tablet_map is not None:
        man["tablets"] = table.tablet_map.to_manifest()
    man_tmp = os.path.join(dirpath, MANIFEST + ".tmp")
    with open(man_tmp, "w") as f:
        json.dump(man, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(man_tmp, os.path.join(dirpath, MANIFEST))
    return os.path.join(dirpath, MANIFEST)


def recover(dirpath: str, tablet_filter=None):
    """Rebuild a ``ShardedTable`` (engine='lsm') after a crash.

    Works from any consistent prefix of (manifest?, snapshot?, WAL): with no
    manifest the whole WAL replays into a table that must be given its
    config via the WAL-only path; with a manifest, snapshot runs load
    directly and only the WAL suffix replays.

    ``tablet_filter`` (iterable of tablet ids, dynamic-tablet stores
    only) restricts the DATA replay to those tablets' frames — the
    distributed-recovery contract: a lost process replays only its own
    tablets' suffix and skips foreign frames without parsing them into
    the store. Tablet-map META frames (splits/moves) always apply, so
    the recovered map matches the cluster's regardless of the filter.
    """
    from ..kvstore import ShardedTable, StoreConfig
    from .wal import WriteAheadLog

    man_path = os.path.join(dirpath, MANIFEST)
    if not os.path.exists(man_path):
        raise FileNotFoundError(
            f"no {MANIFEST} in {dirpath}; call checkpoint() at least once "
            "(WAL-only recovery needs the config the manifest records)")
    with open(man_path) as f:
        man = json.load(f)
    cfg = man["config"]
    table = ShardedTable(
        man.get("name", "recovered"), engine="lsm",
        combiner=cfg["combiner"],
        bloom_bits_per_key=tuple(cfg.get("bloom_bits_per_key", ())) or None,
        bloom_hashes=tuple(cfg.get("bloom_hashes", ())) or None,
        config=StoreConfig.from_manifest(cfg).replace(engine="lsm"))
    snap = os.path.join(dirpath, man["snapshot"])
    if os.path.exists(snap):
        with np.load(snap) as z:
            main_state = {k: z[k] for k in z.files
                          if not k.startswith(_T_PREFIX)}
            table._runs.load_state(main_state)
            if table.t_store is not None:
                t_state = {k[len(_T_PREFIX):]: z[k] for k in z.files
                           if k.startswith(_T_PREFIX)}
                if t_state:
                    table.t_store._runs.load_state(t_state)
    # the tablet map restores BEFORE replay so suffix data frames route
    # through the same topology the live table had at the snapshot point;
    # meta frames then mutate it mid-replay exactly where live did
    if man.get("tablets") and table.tablet_map is not None:
        from ..tablets import TabletMap
        table.tablet_map = TabletMap.from_manifest(man["tablets"])
    # replay the post-snapshot WAL suffix (torn tail drops at CRC check)
    wal_file = os.path.join(dirpath, man["wal"])
    tf = (None if tablet_filter is None
          else {int(t) for t in tablet_filter})
    for item in WriteAheadLog.replay_full(wal_file, start=man["wal_offset"]):
        if item[0] == "meta":
            table._apply_replayed_meta(item[1])
            continue
        _, tid, rows, cols, vals, _pair = item
        if tf is not None and tid is not None and tid not in tf:
            continue  # another process's tablet: skip, don't parse in
        table.insert(np.asarray(rows), np.asarray(cols), np.asarray(vals),
                     _log=False)
    # chop any torn tail BEFORE re-appending: otherwise post-recovery
    # records land after the corrupt bytes and are unreachable next time
    end = WriteAheadLog.truncate_torn_tail(wal_file)
    if end < man["wal_offset"]:
        # the log lost bytes the snapshot already covers (pre-snapshot
        # corruption, possibly the header itself). The data is safe in
        # the snapshot, but appends now land BELOW the recorded offset —
        # invisible to the next replay. Re-anchor the manifest at the
        # truncated end (0 = fully torn: attach_wal lays a fresh header
        # and replay starts over).
        man["wal_offset"] = end
        man_tmp = man_path + ".tmp"
        with open(man_tmp, "w") as f:
            json.dump(man, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(man_tmp, man_path)
    # recovered table keeps journaling to the same WAL
    table.attach_wal(dirpath)
    return table
