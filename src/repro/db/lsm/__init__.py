# LSM storage engine: leveled sorted runs + fused single-dispatch reads
# (bloom/fence gated) + WAL. Wired under ShardedTable(engine="lsm");
# see src/repro/db/README.md.
from .bloom import (bloom_build, bloom_maybe_contains,
                    bloom_maybe_contains_batch, fence_build, num_words,
                    suggest_hashes, theoretical_fp_rate)
from .engine import (LSMRuns, combine_triples, plan_levels,
                     run_query_gated, run_query_rows)
from .manifest import recover, wal_path, write_snapshot
from .wal import WriteAheadLog

__all__ = [
    "LSMRuns", "WriteAheadLog", "bloom_build", "bloom_maybe_contains",
    "bloom_maybe_contains_batch", "combine_triples", "fence_build",
    "num_words", "plan_levels", "recover", "run_query_gated",
    "run_query_rows", "suggest_hashes", "theoretical_fp_rate", "wal_path",
    "write_snapshot",
]
