# LSM storage engine: leveled sorted runs + bloom/fence read path + WAL.
# Wired under ShardedTable(engine="lsm"); see src/repro/db/README.md.
from .bloom import bloom_build, bloom_maybe_contains, fence_build
from .engine import LSMRuns, combine_triples, plan_levels, run_query_rows
from .manifest import recover, wal_path, write_snapshot
from .wal import WriteAheadLog

__all__ = [
    "LSMRuns", "WriteAheadLog", "bloom_build", "bloom_maybe_contains",
    "combine_triples", "fence_build", "plan_levels", "recover",
    "run_query_rows", "wal_path", "write_snapshot",
]
