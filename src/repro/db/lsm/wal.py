"""Append-only write-ahead log for triple batches (durability layer).

Accumulo logs every mutation to a write-ahead log before it reaches the
in-memory map, so a crashed tablet server replays the tail on restart. The
adaptation logs ingest batches of already-encoded (row_id, col_id, value)
triples; string-dictionary durability is a separate concern (ROADMAP).

Record format (little-endian), one record per ``append``::

    u32 n        number of triples (bit 31 = pair-ingest flag)
    u32 crc      crc32 of the payload
    payload      n * int32 rows | n * int32 cols | n * float32 vals

The high bit of ``n`` tags a *pair-ingest* frame: the batch also feeds the
table's transpose sibling (``A^T`` derives deterministically by swapping
rows/cols, so the payload is logged ONCE — one record, one fsync, and
replay can never rebuild half a pair). Readers written before the flag
treat tagged logs as corrupt rather than misparsing them, and untagged
logs replay identically under the new reader.

Replay stops at the first torn or corrupt record (crash-consistent: a
partially flushed tail is discarded, never misparsed). ``tell()`` exposes
the byte offset so a snapshot can mark how much of the log it covers and
recovery can replay only the suffix.
"""
from __future__ import annotations

import os
import struct
import zlib
from time import perf_counter
from typing import Iterator, Optional, Tuple

import numpy as np

from ...obs import default_registry, default_tracer

_HEADER = b"RLSMWAL1"
_REC = struct.Struct("<II")
_PAIR_FLAG = 0x80000000  # high bit of the n field: dual-ingest frame
_N_MASK = _PAIR_FLAG - 1

Batch = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _wal_label(path: str) -> str:
    """Metric label for a log file: its parent dir name (the wal_dir is
    per-table), falling back to the basename."""
    return os.path.basename(os.path.dirname(path)) or os.path.basename(path)


class WriteAheadLog:
    """Single-writer append-only log; safe to re-open for replay."""

    def __init__(self, path: str, sync: bool = False):
        self.path = path
        self.sync = sync
        exists = os.path.exists(path) and os.path.getsize(path) > 0
        self._f = open(path, "ab")
        if not exists:
            self._f.write(_HEADER)
            self._f.flush()
        reg = default_registry()
        self._trace = default_tracer()
        log = _wal_label(path)
        self._c_appends = reg.counter("wal_appends", log=log)
        self._c_bytes = reg.counter("wal_append_bytes", log=log)
        self._c_fsyncs = reg.counter("wal_fsyncs", log=log)
        self._h_append = reg.histogram("wal_latency_s", log=log, op="append")
        self._h_fsync = reg.histogram("wal_latency_s", log=log, op="fsync")
        self._g_backlog = reg.gauge("wal_backlog_bytes", log=log)

    # ------------------------------------------------------------ writing
    def append(self, rows: np.ndarray, cols: np.ndarray,
               vals: np.ndarray, pair: bool = False) -> int:
        """Log one batch; returns the byte offset AFTER the record.

        ``pair=True`` tags the frame as a dual-ingest batch: recovery
        re-derives the transpose sibling's triples from the same payload,
        so both tables of a pair commit or vanish together."""
        t0 = perf_counter()
        with self._trace.span("wal.append", log=_wal_label(self.path),
                              n=len(rows)):
            payload = (np.asarray(rows, "<i4").tobytes()
                       + np.asarray(cols, "<i4").tobytes()
                       + np.asarray(vals, "<f4").tobytes())
            n_field = len(rows) | (_PAIR_FLAG if pair else 0)
            self._f.write(_REC.pack(n_field, zlib.crc32(payload)))
            self._f.write(payload)
            self._f.flush()
            if self.sync:
                t1 = perf_counter()
                os.fsync(self._f.fileno())
                self._c_fsyncs.inc()
                self._h_fsync.observe(perf_counter() - t1)
        self._c_appends.inc()
        self._c_bytes.inc(_REC.size + len(payload))
        self._h_append.observe(perf_counter() - t0)
        return self._f.tell()

    def tell(self) -> int:
        return self._f.tell()

    def refresh_backlog_gauge(self, covered_offset: int = 0) -> int:
        """Health gauge: bytes past ``covered_offset`` (the last
        snapshot's ``wal_offset``) — what a crash right now would have to
        replay. Returns the backlog."""
        backlog = max(0, self.tell() - int(covered_offset))
        self._g_backlog.set(backlog)
        return backlog

    def close(self) -> None:
        self._f.close()

    # ------------------------------------------------------------ replay
    @staticmethod
    def valid_end(path: str) -> int:
        """Byte offset after the last intact record (header if empty)."""
        if not os.path.exists(path):
            return 0
        with open(path, "rb") as f:
            if f.read(len(_HEADER)) != _HEADER:
                return 0
            end = f.tell()
            while True:
                head = f.read(_REC.size)
                if len(head) < _REC.size:
                    return end
                n, crc = _REC.unpack(head)
                n &= _N_MASK
                payload = f.read(12 * n)
                if len(payload) < 12 * n or zlib.crc32(payload) != crc:
                    return end
                end = f.tell()

    @staticmethod
    def truncate_torn_tail(path: str) -> int:
        """Drop a torn/corrupt tail so future appends stay reachable by
        replay (a crash mid-append otherwise poisons the log: records
        appended after the torn bytes would never replay). Returns the
        valid end offset. ``end == 0`` means even the header is torn: the
        file truncates to empty so the next writer lays down a fresh
        header (appending after header garbage would be unreplayable)."""
        end = WriteAheadLog.valid_end(path)
        if os.path.exists(path) and os.path.getsize(path) > end:
            with open(path, "r+b") as f:
                f.truncate(end)
        return end

    @staticmethod
    def replay(path: str, start: int = 0, tagged: bool = False) -> Iterator:
        """Yield logged batches from byte offset ``start`` (0 = whole log).

        Yields ``(rows, cols, vals)`` triples; with ``tagged=True`` each
        item is ``(rows, cols, vals, pair)`` where ``pair`` reports the
        dual-ingest frame flag (pair-aware recovery re-derives ``A^T``
        from the same payload).

        Tolerates a torn tail: a record whose header or payload is short,
        or whose CRC mismatches, ends the iteration (simulated crash).
        """
        if not os.path.exists(path):
            return
        reg = default_registry()
        log = _wal_label(path)
        c_batches = reg.counter("wal_replay_batches", log=log)
        c_bytes = reg.counter("wal_replay_bytes", log=log)
        h_replay = reg.histogram("wal_latency_s", log=log, op="replay")
        t0 = perf_counter()
        with open(path, "rb") as f:
            if f.read(len(_HEADER)) != _HEADER:
                return
            if start > len(_HEADER):
                f.seek(start)
            while True:
                head = f.read(_REC.size)
                if len(head) < _REC.size:
                    break
                n, crc = _REC.unpack(head)
                pair = bool(n & _PAIR_FLAG)
                n &= _N_MASK
                payload = f.read(12 * n)
                if len(payload) < 12 * n or zlib.crc32(payload) != crc:
                    break  # torn/corrupt tail
                rows = np.frombuffer(payload[: 4 * n], "<i4")
                cols = np.frombuffer(payload[4 * n: 8 * n], "<i4")
                vals = np.frombuffer(payload[8 * n:], "<f4")
                c_batches.inc()
                c_bytes.inc(_REC.size + len(payload))
                if tagged:
                    yield rows, cols, vals, pair
                else:
                    yield rows, cols, vals
        h_replay.observe(perf_counter() - t0)
