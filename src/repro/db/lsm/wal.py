"""Append-only write-ahead log for triple batches (durability layer).

Accumulo logs every mutation to a write-ahead log before it reaches the
in-memory map, so a crashed tablet server replays the tail on restart. The
adaptation logs ingest batches of already-encoded (row_id, col_id, value)
triples; string-dictionary durability is a separate concern (ROADMAP).

Record format (little-endian), one record per ``append``::

    u32 n        number of triples (bits 31/30/29 are flags, below)
    u32 crc      crc32 of (tablet-id bytes if any) + payload
    [u32 tablet] present only when bit 30 is set
    payload      n * int32 rows | n * int32 cols | n * float32 vals

Flag bits in the ``n`` field:

  * bit 31 (``_PAIR_FLAG``) — *pair-ingest* frame: the batch also feeds
    the table's transpose sibling (``A^T`` derives deterministically by
    swapping rows/cols, so the payload is logged ONCE — one record, one
    fsync, and replay can never rebuild half a pair).
  * bit 30 (``_TABLET_FLAG``) — the frame carries a ``u32`` tablet id
    between the crc and the payload: every triple in the batch belongs
    to that tablet, so a recovering process can replay ONLY its own
    tablets' suffix by skipping foreign frames without parsing them.
  * bit 29 (``_META_FLAG``) — the payload is a tablet-map operation
    (UTF-8 JSON padded with spaces to a 12-byte multiple, so ``n`` keeps
    the ``12 * n`` payload-length arithmetic): ``{"op": "split", ...}``,
    ``{"op": "move", ...}`` or ``{"op": "merge", ...}``.
    Replay applies these to the tablet map
    at the same log point the live table did, so data frames after the
    op route identically.

Frames without flags are byte-identical to the original format; tagged
and meta frames only appear when a table runs with ``dynamic_tablets``.
Readers written before a flag treat tagged logs as corrupt rather than
misparsing them, and untagged logs replay identically under the new
reader.

Replay stops at the first torn or corrupt record (crash-consistent: a
partially flushed tail is discarded, never misparsed). ``tell()`` exposes
the byte offset so a snapshot can mark how much of the log it covers and
recovery can replay only the suffix.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from time import perf_counter
from typing import Iterator, Optional, Tuple

import numpy as np

from ...obs import default_registry, default_tracer

_HEADER = b"RLSMWAL1"
_REC = struct.Struct("<II")
_TID = struct.Struct("<I")
_PAIR_FLAG = 0x80000000    # bit 31: dual-ingest frame
_TABLET_FLAG = 0x40000000  # bit 30: frame carries a u32 tablet id
_META_FLAG = 0x20000000    # bit 29: payload is a tablet-map op (JSON)
_N_MASK = _META_FLAG - 1

Batch = Tuple[np.ndarray, np.ndarray, np.ndarray]


def _wal_label(path: str) -> str:
    """Metric label for a log file: its parent dir name (the wal_dir is
    per-table), falling back to the basename."""
    return os.path.basename(os.path.dirname(path)) or os.path.basename(path)


def _iter_frames(f) -> Iterator[tuple]:
    """Parse intact frames from an open log positioned past the header.

    Yields ``("meta", op_dict)`` for tablet-map frames and
    ``("data", tablet_id_or_None, rows, cols, vals, pair)`` for triple
    frames. Stops silently at the first torn or corrupt record.
    """
    while True:
        head = f.read(_REC.size)
        if len(head) < _REC.size:
            return
        n_raw, crc = _REC.unpack(head)
        n = n_raw & _N_MASK
        if n_raw & _META_FLAG:
            payload = f.read(12 * n)
            if len(payload) < 12 * n or zlib.crc32(payload) != crc:
                return
            yield "meta", json.loads(payload.decode("utf-8"))
            continue
        extra = b""
        tablet = None
        if n_raw & _TABLET_FLAG:
            extra = f.read(_TID.size)
            if len(extra) < _TID.size:
                return
            tablet = _TID.unpack(extra)[0]
        payload = f.read(12 * n)
        if len(payload) < 12 * n or zlib.crc32(extra + payload) != crc:
            return
        yield ("data", tablet,
               np.frombuffer(payload[: 4 * n], "<i4"),
               np.frombuffer(payload[4 * n: 8 * n], "<i4"),
               np.frombuffer(payload[8 * n:], "<f4"),
               bool(n_raw & _PAIR_FLAG))


class WriteAheadLog:
    """Single-writer append-only log; safe to re-open for replay."""

    def __init__(self, path: str, sync: bool = False):
        self.path = path
        self.sync = sync
        exists = os.path.exists(path) and os.path.getsize(path) > 0
        self._f = open(path, "ab")
        if not exists:
            self._f.write(_HEADER)
            self._f.flush()
        reg = default_registry()
        self._trace = default_tracer()
        log = _wal_label(path)
        self._c_appends = reg.counter("wal_appends", log=log)
        self._c_bytes = reg.counter("wal_append_bytes", log=log)
        self._c_fsyncs = reg.counter("wal_fsyncs", log=log)
        self._h_append = reg.histogram("wal_latency_s", log=log, op="append")
        self._h_fsync = reg.histogram("wal_latency_s", log=log, op="fsync")
        self._g_backlog = reg.gauge("wal_backlog_bytes", log=log)

    # ------------------------------------------------------------ writing
    def append(self, rows: np.ndarray, cols: np.ndarray,
               vals: np.ndarray, pair: bool = False,
               tablet: Optional[int] = None) -> int:
        """Log one batch; returns the byte offset AFTER the record.

        ``pair=True`` tags the frame as a dual-ingest batch: recovery
        re-derives the transpose sibling's triples from the same payload,
        so both tables of a pair commit or vanish together.

        ``tablet`` tags every triple in the frame as belonging to one
        tablet (the caller partitions a mixed batch into per-tablet
        frames), enabling per-tablet suffix replay."""
        t0 = perf_counter()
        with self._trace.span("wal.append", log=_wal_label(self.path),
                              n=len(rows)):
            payload = (np.asarray(rows, "<i4").tobytes()
                       + np.asarray(cols, "<i4").tobytes()
                       + np.asarray(vals, "<f4").tobytes())
            n_field = len(rows) | (_PAIR_FLAG if pair else 0)
            extra = b""
            if tablet is not None:
                n_field |= _TABLET_FLAG
                extra = _TID.pack(int(tablet))
            self._f.write(_REC.pack(n_field, zlib.crc32(extra + payload)))
            if extra:
                self._f.write(extra)
            self._f.write(payload)
            self._f.flush()
            if self.sync:
                t1 = perf_counter()
                os.fsync(self._f.fileno())
                self._c_fsyncs.inc()
                self._h_fsync.observe(perf_counter() - t1)
        self._c_appends.inc()
        self._c_bytes.inc(_REC.size + len(extra) + len(payload))
        self._h_append.observe(perf_counter() - t0)
        return self._f.tell()

    def append_meta(self, op: dict) -> int:
        """Log one tablet-map operation (split/move) as a meta frame;
        returns the byte offset AFTER the record. The op is logged BEFORE
        the in-memory map changes (write-ahead), so replay applies it at
        the same point in the data stream."""
        t0 = perf_counter()
        payload = json.dumps(op, sort_keys=True).encode("utf-8")
        payload += b" " * (-len(payload) % 12)
        n_field = _META_FLAG | (len(payload) // 12)
        self._f.write(_REC.pack(n_field, zlib.crc32(payload)))
        self._f.write(payload)
        self._f.flush()
        if self.sync:
            t1 = perf_counter()
            os.fsync(self._f.fileno())
            self._c_fsyncs.inc()
            self._h_fsync.observe(perf_counter() - t1)
        self._c_appends.inc()
        self._c_bytes.inc(_REC.size + len(payload))
        self._h_append.observe(perf_counter() - t0)
        return self._f.tell()

    def tell(self) -> int:
        return self._f.tell()

    def refresh_backlog_gauge(self, covered_offset: int = 0) -> int:
        """Health gauge: bytes past ``covered_offset`` (the last
        snapshot's ``wal_offset``) — what a crash right now would have to
        replay. Returns the backlog."""
        backlog = max(0, self.tell() - int(covered_offset))
        self._g_backlog.set(backlog)
        return backlog

    def close(self) -> None:
        self._f.close()

    # ------------------------------------------------------------ replay
    @staticmethod
    def valid_end(path: str) -> int:
        """Byte offset after the last intact record (header if empty)."""
        if not os.path.exists(path):
            return 0
        with open(path, "rb") as f:
            if f.read(len(_HEADER)) != _HEADER:
                return 0
            end = f.tell()
            for _ in _iter_frames(f):
                end = f.tell()
            return end

    @staticmethod
    def truncate_torn_tail(path: str) -> int:
        """Drop a torn/corrupt tail so future appends stay reachable by
        replay (a crash mid-append otherwise poisons the log: records
        appended after the torn bytes would never replay). Returns the
        valid end offset. ``end == 0`` means even the header is torn: the
        file truncates to empty so the next writer lays down a fresh
        header (appending after header garbage would be unreplayable)."""
        end = WriteAheadLog.valid_end(path)
        if os.path.exists(path) and os.path.getsize(path) > end:
            with open(path, "r+b") as f:
                f.truncate(end)
        return end

    @staticmethod
    def replay(path: str, start: int = 0, tagged: bool = False) -> Iterator:
        """Yield logged DATA batches from byte offset ``start`` (0 = whole
        log); tablet-map meta frames are skipped (use ``replay_full`` to
        see them).

        Yields ``(rows, cols, vals)`` triples; with ``tagged=True`` each
        item is ``(rows, cols, vals, pair)`` where ``pair`` reports the
        dual-ingest frame flag (pair-aware recovery re-derives ``A^T``
        from the same payload).

        Tolerates a torn tail: a record whose header or payload is short,
        or whose CRC mismatches, ends the iteration (simulated crash).
        """
        for item in WriteAheadLog.replay_full(path, start=start):
            if item[0] != "data":
                continue
            _, _tid, rows, cols, vals, pair = item
            if tagged:
                yield rows, cols, vals, pair
            else:
                yield rows, cols, vals

    @staticmethod
    def replay_full(path: str, start: int = 0) -> Iterator[tuple]:
        """Yield EVERY intact frame from byte offset ``start``:
        ``("data", tablet_id_or_None, rows, cols, vals, pair)`` for
        triple batches and ``("meta", op_dict)`` for tablet-map ops, in
        log order. Tablet-aware recovery filters data frames by tablet id
        and applies meta frames to its map as they stream past."""
        if not os.path.exists(path):
            return
        reg = default_registry()
        log = _wal_label(path)
        c_batches = reg.counter("wal_replay_batches", log=log)
        c_bytes = reg.counter("wal_replay_bytes", log=log)
        h_replay = reg.histogram("wal_latency_s", log=log, op="replay")
        t0 = perf_counter()
        with open(path, "rb") as f:
            if f.read(len(_HEADER)) != _HEADER:
                return
            if start > len(_HEADER):
                f.seek(start)
            pos = f.tell()
            for item in _iter_frames(f):
                c_batches.inc()
                c_bytes.inc(f.tell() - pos)
                pos = f.tell()
                yield item
        h_replay.observe(perf_counter() - t0)
