"""Per-run bloom filters — packed uint32 bitsets with vectorized hashing.

Accumulo keeps a bloom filter per RFile so point lookups skip files that
cannot contain the key; here every sorted run (L0 flush or leveled run)
carries one over its ROW ids (queries are row point-lookups). Build and
probe are pure jnp: k multiplicative xor-shift hashes, a boolean scatter
(collision-safe, unlike packed-word adds), then a pack to uint32 words so
the resident state is bits/8 bytes per key.

Sizing is per run: ``bits_per_key`` and ``n_hashes`` are exposed so deep
levels (which absorb most negative lookups) can carry denser filters than
L0 runs (ROADMAP "Bloom sizing"). The defaults — 8 bits/key, 4 hashes —
give ~2.4% false positives at full occupancy; the theoretical rate for a
filter of m bits, n keys, k hashes is ``(1 - exp(-k*n/m))**k``
(``theoretical_fp_rate``). A false positive costs one needless rank
search, never a wrong result.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from ...kernels.common import I32_MAX

NUM_HASHES = 4
BITS_PER_KEY = 8

# odd 32-bit constants (xxhash/murmur finalizer family); len() bounds the
# largest usable n_hashes
_MULTS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
          0x165667B1, 0xD6E8FEB9, 0xCC9E2D51, 0x1B873593)

MAX_HASHES = len(_MULTS)


def num_words(run_capacity: int, bits_per_key: int = BITS_PER_KEY) -> int:
    """uint32 words for a run of ``run_capacity`` keys (pow2, >= 2)."""
    bits = max(64, run_capacity * bits_per_key)
    bits = 1 << (bits - 1).bit_length()
    return bits // 32


def theoretical_fp_rate(n_keys: int, n_words: int, n_hashes: int) -> float:
    """Classic bloom bound: (1 - e^{-kn/m})^k for m = 32 * n_words bits."""
    if n_keys == 0:
        return 0.0
    m = 32 * n_words
    return (1.0 - math.exp(-n_hashes * n_keys / m)) ** n_hashes


def suggest_hashes(bits_per_key: int) -> int:
    """fp-optimal hash count k = ln2 * bits/key, clamped to _MULTS."""
    return max(1, min(MAX_HASHES, round(math.log(2) * bits_per_key)))


def _hash(keys: jax.Array, mult: int, n_bits: int) -> jax.Array:
    """Multiplicative xor-shift hash of int32 keys into [0, n_bits)."""
    h = keys.astype(jnp.uint32) * jnp.uint32(mult)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 13)
    return (h & jnp.uint32(n_bits - 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_words", "n_hashes"))
def bloom_build(rows: jax.Array, n_words: int,
                n_hashes: int = NUM_HASHES) -> jax.Array:
    """Build a packed filter over the valid (!= I32_MAX) row ids.

    Scatters into a boolean bitset first (set() is idempotent, so same-word
    collisions are safe), then packs 32 bools per uint32 word.
    """
    n_bits = n_words * 32
    valid = rows != I32_MAX
    bits = jnp.zeros((n_bits,), jnp.bool_)
    for mult in _MULTS[:n_hashes]:
        idx = jnp.where(valid, _hash(rows, mult, n_bits), n_bits)
        bits = bits.at[idx].set(True, mode="drop")
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (bits.reshape(n_words, 32).astype(jnp.uint32) * weights).sum(
        axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_hashes",))
def bloom_maybe_contains(words: jax.Array, q: jax.Array,
                         n_hashes: int = NUM_HASHES) -> jax.Array:
    """bool[Q]: False guarantees the row is absent from the run."""
    n_bits = words.shape[-1] * 32
    hit = jnp.ones(q.shape, jnp.bool_)
    for mult in _MULTS[:n_hashes]:
        h = _hash(q, mult, n_bits)
        bit = (words[..., h >> 5] >> (h & 31).astype(jnp.uint32)) & 1
        hit = hit & (bit == 1)
    return hit


def bloom_maybe_contains_batch(words: jax.Array, q: jax.Array,
                               n_hashes: int = NUM_HASHES) -> jax.Array:
    """bool[K, Q] probe of a stacked batch of filters ``words[K, W]`` —
    the fused read path probes every resident run of a shard inside one
    dispatch. Not jitted standalone: callers trace it inside their own jit."""
    n_bits = words.shape[-1] * 32
    hit = jnp.ones((words.shape[0], q.shape[0]), jnp.bool_)
    for mult in _MULTS[:n_hashes]:
        h = _hash(q, mult, n_bits)                       # [Q]
        bit = (words[:, h >> 5] >> (h & 31).astype(jnp.uint32)) & 1
        hit = hit & (bit == 1)
    return hit


def fence_build(rows: jax.Array, block: int) -> jax.Array:
    """Fence pointers: first row id of every ``block``-entry block.

    The in-memory analogue of RFile index blocks: a query's start position
    is bracketed to one block by searching the (tiny) fence array, and runs
    whose [fence[0], last-row] range excludes every queried row are skipped
    without touching the run itself.
    """
    return rows[::block]
