"""Per-run bloom filters — packed uint32 bitsets with vectorized hashing.

Accumulo keeps a bloom filter per RFile so point lookups skip files that
cannot contain the key; here every sorted run (L0 flush or leveled run)
carries one over its ROW ids (queries are row point-lookups). Build and
probe are pure jnp: k multiplicative xor-shift hashes, a boolean scatter
(collision-safe, unlike packed-word adds), then a pack to uint32 words so
the resident state is bits/8 bytes per key.

Sizing: ``BITS_PER_KEY`` = 8 with ``NUM_HASHES`` = 4 gives ~2.4% false
positives at full occupancy — each false positive costs one needless rank
search, never a wrong result.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...kernels.common import I32_MAX

NUM_HASHES = 4
BITS_PER_KEY = 8

# odd 32-bit constants (xxhash/murmur finalizer family)
_MULTS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)


def num_words(run_capacity: int) -> int:
    """uint32 words for a run of ``run_capacity`` keys (pow2, >= 2)."""
    bits = max(64, run_capacity * BITS_PER_KEY)
    bits = 1 << (bits - 1).bit_length()
    return bits // 32


def _hash(keys: jax.Array, mult: int, n_bits: int) -> jax.Array:
    """Multiplicative xor-shift hash of int32 keys into [0, n_bits)."""
    h = keys.astype(jnp.uint32) * jnp.uint32(mult)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 13)
    return (h & jnp.uint32(n_bits - 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_words",))
def bloom_build(rows: jax.Array, n_words: int) -> jax.Array:
    """Build a packed filter over the valid (!= I32_MAX) row ids.

    Scatters into a boolean bitset first (set() is idempotent, so same-word
    collisions are safe), then packs 32 bools per uint32 word.
    """
    n_bits = n_words * 32
    valid = rows != I32_MAX
    bits = jnp.zeros((n_bits,), jnp.bool_)
    for mult in _MULTS[:NUM_HASHES]:
        idx = jnp.where(valid, _hash(rows, mult, n_bits), n_bits)
        bits = bits.at[idx].set(True, mode="drop")
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return (bits.reshape(n_words, 32).astype(jnp.uint32) * weights).sum(
        axis=1, dtype=jnp.uint32)


@jax.jit
def bloom_maybe_contains(words: jax.Array, q: jax.Array) -> jax.Array:
    """bool[Q]: False guarantees the row is absent from the run."""
    n_bits = words.shape[-1] * 32
    hit = jnp.ones(q.shape, jnp.bool_)
    for mult in _MULTS[:NUM_HASHES]:
        h = _hash(q, mult, n_bits)
        bit = (words[..., h >> 5] >> (h & 31).astype(jnp.uint32)) & 1
        hit = hit & (bit == 1)
    return hit


def fence_build(rows: jax.Array, block: int) -> jax.Array:
    """Fence pointers: first row id of every ``block``-entry block.

    The in-memory analogue of RFile index blocks: a query's start position
    is bracketed to one block by searching the (tiny) fence array, and runs
    whose [fence[0], last-row] range excludes every queried row are skipped
    without touching the run itself.
    """
    return rows[::block]
