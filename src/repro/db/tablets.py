"""Dynamic tablet map: row-range → tablet → owning shard (Accumulo model).

``ShardedTable`` historically hashed rows to a fixed shard count with
``shard_of`` (uniform range pre-split). Real traffic is Zipfian: one hot
key range saturates a shard while its peers idle. Accumulo's answer is
*tablets* — contiguous row ranges that SPLIT when hot and MIGRATE between
tablet servers to balance load. This module is the map of that state:

  * ``splits``     — sorted interior boundary keys; tablet ``i`` owns
                     ``[splits[i-1], splits[i])`` (first/last tablet
                     extend to 0 / ``id_capacity``);
  * ``tablet_ids`` — STABLE identity per tablet. A split keeps the left
                     half's id and mints a fresh one for the right; a
                     move never changes ids. WAL frames tag batches with
                     the tablet id, so "replay only my tablets' suffix"
                     is a well-defined filter at ANY log point;
  * ``owners``     — physical shard currently serving each tablet;
  * ``loads``      — decayed ingest/query entry counts per tablet, the
                     split/rebalance policy signal.

``TabletMap.uniform`` reproduces ``shard_of`` exactly (same boundaries,
owner ``i`` for tablet ``i``), so enabling ``dynamic_tablets`` changes
nothing until the first split. The map round-trips through the snapshot
manifest (format 3, ``lsm.manifest``) and splits/moves journal as WAL
meta frames, so recovery rebuilds the exact topology.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


class TabletMap:
    """Mutable row-range partition with stable tablet identities."""

    def __init__(self, splits: np.ndarray, tablet_ids: np.ndarray,
                 owners: np.ndarray, id_capacity: int, num_shards: int,
                 next_id: int, loads: Optional[np.ndarray] = None):
        self.splits = np.asarray(splits, np.int64)
        self.tablet_ids = np.asarray(tablet_ids, np.int32)
        self.owners = np.asarray(owners, np.int32)
        self.id_capacity = int(id_capacity)
        self.num_shards = int(num_shards)
        self.next_id = int(next_id)
        self.loads = (np.zeros(len(self.tablet_ids), np.float64)
                      if loads is None else np.asarray(loads, np.float64))
        if len(self.splits) != len(self.tablet_ids) - 1:
            raise ValueError("splits must have one fewer entry than tablets")
        if (np.diff(self.splits) <= 0).any():
            raise ValueError("splits must be strictly increasing")

    # ------------------------------------------------------------ factory
    @classmethod
    def uniform(cls, num_shards: int, id_capacity: int) -> "TabletMap":
        """One tablet per shard with the SAME boundaries as ``shard_of``:
        tablet ``s`` owns ``[ceil(s*cap/S), ceil((s+1)*cap/S))`` — the id
        ranges the static hash already assigns, so a fresh dynamic table
        routes identically to a static one until the first split."""
        s = np.arange(1, num_shards, dtype=np.int64)
        splits = -(-(s * id_capacity) // num_shards)  # ceil
        return cls(splits, np.arange(num_shards, dtype=np.int32),
                   np.arange(num_shards, dtype=np.int32),
                   id_capacity, num_shards, next_id=num_shards)

    # ------------------------------------------------------------ lookup
    @property
    def n(self) -> int:
        return len(self.tablet_ids)

    def tablet_of(self, ids: np.ndarray) -> np.ndarray:
        """Tablet INDEX (not id) per row id."""
        return np.searchsorted(self.splits, np.asarray(ids, np.int64),
                               side="right")

    def owner_of(self, ids: np.ndarray) -> np.ndarray:
        """Owner shard per row id (the dynamic ``shard_of``)."""
        return self.owners[self.tablet_of(ids)].astype(np.int32)

    def index_of(self, tablet_id: int) -> int:
        idx = np.flatnonzero(self.tablet_ids == np.int32(tablet_id))
        if len(idx) != 1:
            raise KeyError(f"unknown tablet id {tablet_id}")
        return int(idx[0])

    def ranges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(lo[T], hi[T]) row-range bounds per tablet."""
        lo = np.concatenate([[0], self.splits])
        hi = np.concatenate([self.splits, [self.id_capacity]])
        return lo, hi

    def range_of(self, tablet_id: int) -> Tuple[int, int]:
        i = self.index_of(tablet_id)
        lo, hi = self.ranges()
        return int(lo[i]), int(hi[i])

    def segments(self, lo: int, hi: int) -> List[Tuple[int, int, int]]:
        """Cover ``[lo, hi)`` with per-owner sub-ranges in KEY order,
        coalescing adjacent tablets that share an owner — a range scan
        issues one fused dispatch per segment and the concatenated
        results stay globally (row, col)-sorted."""
        lo, hi = max(int(lo), 0), min(int(hi), self.id_capacity)
        if hi <= lo:
            return []
        i0 = int(np.searchsorted(self.splits, lo, side="right"))
        i1 = int(np.searchsorted(self.splits, hi - 1, side="right"))
        t_lo, t_hi = self.ranges()
        out: List[Tuple[int, int, int]] = []
        for i in range(i0, i1 + 1):
            s = int(self.owners[i])
            a, b = max(lo, int(t_lo[i])), min(hi, int(t_hi[i]))
            if out and out[-1][0] == s and out[-1][2] == a:
                out[-1] = (s, out[-1][1], b)
            else:
                out.append((s, a, b))
        return out

    # ---------------------------------------------------------- mutation
    def split(self, tablet_id: int, key: int,
              new_id: Optional[int] = None) -> int:
        """Split a tablet at interior ``key``: the left half keeps
        ``tablet_id`` and its range becomes ``[lo, key)``; the right half
        ``[key, hi)`` gets a FRESH id (``new_id`` pins it during WAL
        replay) on the same owner. Metadata only — no data moves.
        Returns the right half's id."""
        i = self.index_of(tablet_id)
        lo, hi = self.ranges()
        if not int(lo[i]) < int(key) < int(hi[i]):
            raise ValueError(
                f"split key {key} outside tablet interior "
                f"({int(lo[i])}, {int(hi[i])})")
        nid = self.next_id if new_id is None else int(new_id)
        self.next_id = max(self.next_id, nid) + 1
        self.splits = np.insert(self.splits, i, np.int64(key))
        self.tablet_ids = np.insert(self.tablet_ids, i + 1, np.int32(nid))
        self.owners = np.insert(self.owners, i + 1, self.owners[i])
        half = self.loads[i] / 2.0
        self.loads[i] = half
        self.loads = np.insert(self.loads, i + 1, half)
        return nid

    def move(self, tablet_id: int, new_owner: int) -> int:
        """Reassign a tablet's owner shard; returns the OLD owner. The
        caller migrates the physical entries (``ShardedTable`` scans,
        clears, and re-routes the source shard)."""
        i = self.index_of(tablet_id)
        old = int(self.owners[i])
        self.owners[i] = np.int32(new_owner)
        return old

    def merge(self, tablet_id: int) -> int:
        """Merge a tablet with its RIGHT neighbor: the pair must share an
        owner (the caller moves one first otherwise), the left keeps its
        id and absorbs the right's range and load. Metadata only — both
        halves already live on the same shard. Returns the retired right
        tablet's id."""
        i = self.index_of(tablet_id)
        if i + 1 >= self.n:
            raise ValueError(f"tablet {tablet_id} has no right neighbor")
        if self.owners[i] != self.owners[i + 1]:
            raise ValueError(
                "merge requires both tablets on one shard "
                f"({int(self.owners[i])} != {int(self.owners[i + 1])})")
        gone = int(self.tablet_ids[i + 1])
        self.splits = np.delete(self.splits, i)
        self.tablet_ids = np.delete(self.tablet_ids, i + 1)
        self.owners = np.delete(self.owners, i + 1)
        self.loads[i] += self.loads[i + 1]
        self.loads = np.delete(self.loads, i + 1)
        return gone

    # ------------------------------------------------------- load signal
    def record_load(self, tablet_idx: np.ndarray,
                    weight: float = 1.0) -> None:
        """Accumulate per-tablet load from one batch's tablet indices."""
        if len(tablet_idx) == 0:
            return
        self.loads += weight * np.bincount(
            np.asarray(tablet_idx), minlength=self.n).astype(np.float64)

    def touch_range(self, lo: int, hi: int) -> None:
        """Count a range scan against every tablet it intersects."""
        if hi <= lo:
            return
        i0 = int(np.searchsorted(self.splits, max(int(lo), 0), side="right"))
        i1 = int(np.searchsorted(self.splits, int(hi) - 1, side="right"))
        self.loads[i0:i1 + 1] += 1.0

    def shard_loads(self) -> np.ndarray:
        """Recorded load aggregated onto the owning shards, [S]."""
        return np.bincount(self.owners, weights=self.loads,
                           minlength=self.num_shards)

    def shard_balance(self) -> float:
        """max/mean per-shard load — 1.0 is perfectly balanced."""
        per = self.shard_loads()
        mean = per.mean()
        return float(per.max() / mean) if mean > 0 else 1.0

    def decay(self, factor: float = 0.5) -> None:
        """Exponential-decay the load signal so the policy tracks the
        RECENT workload instead of all history."""
        self.loads *= factor

    # ------------------------------------------------- warm-read probing
    def sample_shard_ids(self, shard: int, per_shard: int = 18) -> np.ndarray:
        """~``per_shard`` unique ids drawn from the ranges ``shard``
        owns. ``warm_reads`` uses this instead of a uniform id-space
        probe: under a skewed map the uniform probe can hand a
        narrow-range shard <= 8 ids (point-bucket shape only) and its
        query tile would compile lazily on the first real batch."""
        lo, hi = self.ranges()
        mine = np.flatnonzero(self.owners == np.int32(shard))
        if len(mine) == 0:
            return np.zeros(0, np.int32)
        widths = (hi[mine] - lo[mine]).astype(np.float64)
        total = widths.sum()
        out = []
        for i, w in zip(mine, widths):
            k = min(int(w), max(2, int(round(per_shard * w / total))))
            out.append(np.linspace(lo[i], hi[i] - 1, k).astype(np.int64))
        return np.unique(np.concatenate(out)).astype(np.int32)

    # ------------------------------------------------------- persistence
    def to_manifest(self) -> dict:
        """JSON-ready record for the snapshot manifest (format 3)."""
        return {
            "splits": [int(x) for x in self.splits],
            "tablet_ids": [int(x) for x in self.tablet_ids],
            "owners": [int(x) for x in self.owners],
            "id_capacity": self.id_capacity,
            "num_shards": self.num_shards,
            "next_id": self.next_id,
        }

    @classmethod
    def from_manifest(cls, d: dict) -> "TabletMap":
        return cls(np.asarray(d["splits"], np.int64),
                   np.asarray(d["tablet_ids"], np.int32),
                   np.asarray(d["owners"], np.int32),
                   d["id_capacity"], d["num_shards"], d["next_id"])

    # ----------------------------------------------------- device routing
    def device_routing(self, max_tablets: int):
        """(splits[max_tablets-1], owners[max_tablets]) int32 arrays for
        the SPMD ingest step: splits pad with ``id_capacity`` (a sentinel
        no valid id reaches, so padded tablets are never selected) and
        owners pad with 0. Padding to a STATIC ``max_tablets`` means a
        split or move changes array VALUES, never shapes — the compiled
        mesh step survives every rebalance without retracing."""
        if self.n > max_tablets:
            raise ValueError(
                f"{self.n} tablets exceed device budget {max_tablets}")
        splits = np.full(max_tablets - 1, self.id_capacity, np.int32)
        splits[:len(self.splits)] = self.splits.astype(np.int32)
        owners = np.zeros(max_tablets, np.int32)
        owners[:self.n] = self.owners
        return splits, owners
