"""Graphulo-style server-side GraphBLAS ops — the paper's §VI future work.

Graphulo implements GraphBLAS kernels as Accumulo server-side iterators so
graph algorithms run *inside* the database. The mesh analogue: operate on
the shard-resident tablet arrays directly (no client round-trip through
string space), using the SpMV Pallas kernel / vectorized SpGEMM on the
dictionary-encoded ids, and write results back through the combiner path.

Provided kernels (GraphBLAS-style over the tropical/arithmetic semiring):
  * ``table_spmv``  — y = A @ x           (BFS / PageRank steps)
  * ``table_spgemm``— C = A @ B           (multi-hop reachability), result
                      ingested into a new table with a sum combiner
  * ``table_tricount`` — triangle counting via C = A @ A masked by A
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import sparsegemm as sg
from ..kernels.spmv import ell_from_coo, spmv_ell
from .connector import DBserver, Table, TablePair


def _table_coo(table: Table):
    """Server-side view: dictionary-encoded triples straight off the shards."""
    r, c, v = table.store.scan()
    order = np.lexsort((c, r))
    return r[order].astype(np.int64), c[order].astype(np.int64), \
        v[order].astype(np.float64)


def _dim(server: DBserver) -> int:
    return len(server.keydict)


def table_spmv(table, x: np.ndarray, use_pallas: bool = False) -> np.ndarray:
    """y = A @ x over vertex-id space (x indexed by key id)."""
    t = table.table if isinstance(table, TablePair) else table
    r, c, v = _table_coo(t)
    n = _dim(t.server)
    if use_pallas:
        cols, vals = ell_from_coo(r.astype(np.int64), c, v, n)
        return np.asarray(spmv_ell(jnp.asarray(cols), jnp.asarray(vals),
                                   jnp.asarray(x, np.float32)))
    return sg.spmv((r, c, v), np.asarray(x, np.float64))[: n]


def table_spgemm(table_a, table_b, server: DBserver,
                 out_name: Optional[str] = None):
    """C = A @ B server-side; optionally ingest C into ``out_name``.

    Returns (rows, cols, vals) id-space triples; when ``out_name`` is given
    the result lands in a new table through the normal combiner path and is
    queryable with Listing-1 syntax immediately.
    """
    ta = table_a.table if isinstance(table_a, TablePair) else table_a
    tb = table_b.table if isinstance(table_b, TablePair) else table_b
    a = _table_coo(ta)
    b = _table_coo(tb)
    n = _dim(server)
    rr, cc, vv = sg.spgemm(a, b, n)
    if out_name is not None:
        out = server[out_name]
        keys = server.keydict.decode(np.arange(n))
        out.put_triple(keys[rr], keys[cc], vv)
        return out
    return rr, cc, vv


def table_tricount(pair: TablePair, server: DBserver) -> int:
    """Triangles = sum(A ∘ (A @ A)) / 6 on the symmetrized pattern."""
    t = pair.table if isinstance(pair, TablePair) else pair
    r, c, v = _table_coo(t)
    keep = r != c                                     # drop self loops
    r, c = r[keep], c[keep]
    # symmetrize the pattern
    rs = np.concatenate([r, c])
    cs = np.concatenate([c, r])
    rs, cs, vs = sg.coalesce(rs, cs, np.ones(len(rs)), "max")
    n = _dim(server)
    rr, cc, vv = sg.spgemm((rs, cs, vs), (rs, cs, vs), n)
    # hadamard mask with A: count paths of length 2 that close
    akeys = set(zip(rs.tolist(), cs.tolist()))
    total = sum(val for a, b, val in zip(rr.tolist(), cc.tolist(), vv.tolist())
                if (a, b) in akeys)
    return int(round(total / 6.0))
