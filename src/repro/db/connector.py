"""The D4M.jl database connector API — paper Listing 1, verbatim workflow:

    dbinit()
    DB = dbsetup("mydb02", "db.conf")
    Tedge = DB["my_Tedge", "my_TedgeT"]     # table pair (auto-transpose)
    TedgeDeg = DB["my_TedgeDeg"]
    put(Tedge, A)
    Arow = Tedge["e1,", :]
    Acol = Tedge[:, "v1,"]
    delete(Tedge); delete(TedgeDeg)

The paper's contribution is hiding JavaCall/JVM friction behind this API;
our adaptation hides dictionary-encoding, fixed-capacity padding, and mesh
sharding behind the *same* API (DESIGN §2).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple, Union

import numpy as np

from ..core.assoc import Assoc, split_str
from ..core.dictionary import StringDict
from ..obs import Histogram, default_registry
from . import batching
from .kvstore import ShardedTable

_INITIALIZED = False


# ---------------------------------------------------------------------------
# String-dictionary durability (ROADMAP "dictionary durability"): the WAL
# journals encoded int triples, so recovering *string-keyed* queries needs
# the dictionaries too. Each dict persists as a checkpoint snapshot
# (<stem>.json, the whole id->string list) plus an append-only journal
# (<stem>.log, one JSON line per newly interned string, flushed before the
# triple batch that uses those ids reaches the triple WAL). Recovery loads
# the snapshot and replays the journal suffix; a torn last line is
# discarded — its ids can never appear in the triple WAL, which is always
# flushed after the dict journal.
# ---------------------------------------------------------------------------
def _dict_paths(dirpath: str, stem: str) -> Tuple[str, str]:
    return (os.path.join(dirpath, stem + ".json"),
            os.path.join(dirpath, stem + ".log"))


def _load_dict(dirpath: str, stem: str) -> StringDict:
    """Rebuild a StringDict from its checkpoint + journal suffix."""
    jpath, lpath = _dict_paths(dirpath, stem)
    strs = []
    if os.path.exists(jpath):
        with open(jpath) as f:
            strs = json.load(f)
    seen = set(strs)
    if os.path.exists(lpath):
        with open(lpath, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    s = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail from a crash mid-append
                # a crash BETWEEN checkpoint's snapshot write and its
                # journal reset leaves journal lines the snapshot already
                # holds; appends are strictly-new strings in id order, so
                # membership dedup restores the exact id positions
                if s not in seen:
                    strs.append(s)
                    seen.add(s)
    return StringDict.from_strings(strs)


class _DictJournal:
    """Open append handle for one dictionary's .log file."""

    def __init__(self, dirpath: str, stem: str):
        self.jpath, self.lpath = _dict_paths(dirpath, stem)
        self._f = open(self.lpath, "a", encoding="utf-8")

    def append(self, strings) -> None:
        for s in strings:
            self._f.write(json.dumps(s) + "\n")
        self._f.flush()

    def checkpoint(self, d: StringDict) -> None:
        """Snapshot the whole dict and reset the journal (compaction)."""
        d.save(self.jpath)
        self._f.close()
        self._f = open(self.lpath, "w", encoding="utf-8")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def dbinit() -> None:
    """JVM-init analogue: warm the device runtime once per process."""
    global _INITIALIZED
    if not _INITIALIZED:
        import jax
        jax.devices()  # touch the backend
        _INITIALIZED = True


def dbsetup(instance: str, conf: Optional[dict] = None, **kw) -> "DBserver":
    """Create a server binding (conf dict stands in for db.conf)."""
    dbinit()
    cfg = dict(conf or {})
    cfg.update(kw)
    return DBserver(instance, **cfg)


class DBserver:
    """Connection holder; indexing binds tables (creating them on demand)."""

    def __init__(self, instance: str, num_shards: int = 4,
                 capacity_per_shard: int = 1 << 18, batch_cap: int = 1 << 15,
                 id_capacity: int = 1 << 22,
                 char_budget: int = batching.DEFAULT_CHAR_BUDGET,
                 use_pallas: bool = False,  # True = TPU kernels (interpret
                 # mode on CPU is validation-only; XLA path is the CPU path)
                 engine: str = "lsm",  # storage engine: "lsm" (leveled
                 # runs, db/lsm) or "single" (legacy one-run tablet)
                 fused_reads: bool = True,  # LSM point reads fused-dispatch
                 fused_q_limit: int = 512,  # query tile: larger batches
                 # split into fused_q_limit-wide tiles (one jit entry each)
                 l0_slots: int = 4,   # LSM L0 runs per shard before a
                 fanout: int = 4,     # major compaction; level growth rate
                 wal_root: str = None):  # durability root: each table logs
                 # to <wal_root>/<table>/, the shared key dictionary to
                 # <wal_root>/keydict.{json,log}
        assert num_shards * id_capacity < 2 ** 31, "id space must fit int32 routing"
        self.instance = instance
        self.num_shards = num_shards
        self.capacity_per_shard = capacity_per_shard
        self.batch_cap = batch_cap
        self.id_capacity = id_capacity
        self.char_budget = char_budget
        self.use_pallas = use_pallas
        self.engine = engine
        self.fused_reads = fused_reads
        self.fused_q_limit = fused_q_limit
        self.l0_slots = l0_slots
        self.fanout = fanout
        self.keydict = StringDict()          # shared row/col key universe
        self._sorted_keys: Optional[np.ndarray] = None
        self.tables: dict = {}
        self.wal_root: Optional[str] = None
        self._keydict_journal: Optional[_DictJournal] = None
        if wal_root is not None:
            self.attach_wal_root(wal_root)

    def attach_wal_root(self, wal_root: str) -> None:
        """Enable durability under ``wal_root``. Call AFTER loading any
        pre-existing dictionary state (recover_connector does)."""
        os.makedirs(wal_root, exist_ok=True)
        if self._keydict_journal is not None:
            self._keydict_journal.close()
        self.wal_root = wal_root
        self._keydict_journal = _DictJournal(wal_root, "keydict")

    # ------------------------------------------------------------- binding
    def __getitem__(self, names: Union[str, Tuple[str, str]]):
        if isinstance(names, tuple):
            t, tt = names
            return TablePair(self._bind(t), self._bind(tt))
        return self._bind(names)

    def _bind(self, name: str) -> "Table":
        if name not in self.tables:
            self.tables[name] = Table(self, name)
        return self.tables[name]

    def ls(self):
        return sorted(self.tables)

    def drop(self, name: str) -> None:
        self.tables.pop(name, None)

    # ----------------------------------------------------- key resolution
    def encode_keys(self, strs: np.ndarray) -> np.ndarray:
        before = len(self.keydict)
        ids = self.keydict.encode(strs)
        if ids.size and ids.max() >= self.id_capacity:
            raise OverflowError("key universe exceeded id_capacity")
        if self._keydict_journal is not None and len(self.keydict) > before:
            # journal newly interned strings (in id order) BEFORE any
            # triple using those ids can reach a table WAL
            self._keydict_journal.append(self.keydict._to_str[before:])
        self._sorted_keys = None  # invalidate range-query snapshot
        return ids

    def checkpoint_keydict(self) -> None:
        """Snapshot the shared key dictionary + reset its journal."""
        if self._keydict_journal is None:
            raise ValueError("checkpoint_keydict() needs a wal_root")
        self._keydict_journal.checkpoint(self.keydict)

    def _snapshot(self):
        if self._sorted_keys is None or len(self._sorted_keys) != len(self.keydict):
            keys = self.keydict.decode(np.arange(len(self.keydict)))
            order = np.argsort(keys)
            self._sorted_keys = keys[order]
            self._sorted_ids = np.arange(len(keys), dtype=np.int32)[order]
        return self._sorted_keys, self._sorted_ids

    def _span_ids(self, lo_key: str, hi_key: str) -> np.ndarray:
        """Sorted dict ids of every key in the STRING range
        [lo_key, hi_key] (both inclusive — the one searchsorted span both
        the range and prefix selectors reduce to, shared by the id-list
        and scan-plan resolvers so they can never disagree)."""
        skeys, sids = self._snapshot()
        lo = np.searchsorted(skeys, lo_key, side="left")
        hi = np.searchsorted(skeys, hi_key, side="right")
        return np.sort(sids[lo:hi]).astype(np.int32)

    def resolve_selector(self, sel) -> Optional[np.ndarray]:
        """D4M selector -> row ids; None means 'all' (full scan).

        Accumulo scans string ranges server-side; the adaptation expands
        range/prefix selectors to id lists via the key dictionary (it knows
        the whole key universe), then issues batched point queries.
        """
        if sel is None or sel == ":" or (isinstance(sel, slice) and sel == slice(None)):
            return None
        toks = split_str(sel) if isinstance(sel, str) else np.asarray(
            [str(t) for t in np.asarray(sel).ravel()], dtype=object)
        if len(toks) == 3 and toks[1] == ":":
            return self._span_ids(toks[0], toks[2])
        out = []
        for t in toks:
            if t.endswith("*"):
                out.append(self._span_ids(t[:-1], t[:-1] + "￿"))
            else:
                i = self.keydict.get(t)
                if i >= 0:
                    out.append(np.asarray([i], dtype=np.int32))
        if not out:
            return np.zeros(0, dtype=np.int32)
        return np.unique(np.concatenate(out))

    # a dict-range id set denser than this scans the covering id range in
    # one fused dispatch and filters the stragglers on the host; sparser
    # sets fall back to batched point queries
    RANGE_SCAN_DENSITY = 0.5

    def resolve_selector_plan(self, sel):
        """D4M selector -> read plan, WITHOUT materializing an id list
        when a server-side range scan can serve it (Accumulo scans string
        ranges tablet-side; ``T["a,:,c,", :]`` should not expand to
        O(range) point queries).

        Returns one of::

            ("all", None)              full scan
            ("ids", ids)               batched point queries (fallback)
            ("range", (lo, hi, filt))  contiguous id-range scan [lo, hi);
                                       ``filt`` is None when the dict ids
                                       inside the string range are exactly
                                       [lo, hi) (scan alone answers), else
                                       the sorted id subset to keep after
                                       a dense-superset scan

        Range/prefix selectors map through the key dictionary's sorted-key
        snapshot: the matching ids are contiguous whenever keys were
        interned in lexicographic order (sorted ingest, the common D4M
        bulk-load shape) — then the scan needs no id list at all.
        """
        if sel is None or sel == ":" or (isinstance(sel, slice)
                                         and sel == slice(None)):
            return ("all", None)
        toks = split_str(sel) if isinstance(sel, str) else np.asarray(
            [str(t) for t in np.asarray(sel).ravel()], dtype=object)
        span_ids = None
        if len(toks) == 3 and toks[1] == ":":
            span_ids = self._span_ids(toks[0], toks[2])
        elif len(toks) == 1 and toks[0].endswith("*"):
            span_ids = self._span_ids(toks[0][:-1], toks[0][:-1] + "￿")
        if span_ids is None:
            return ("ids", self.resolve_selector(sel))
        if len(span_ids) == 0:
            return ("ids", span_ids)
        lo_id, hi_id = int(span_ids[0]), int(span_ids[-1]) + 1
        span = hi_id - lo_id
        if span == len(span_ids):
            return ("range", (lo_id, hi_id, None))
        if len(span_ids) >= self.RANGE_SCAN_DENSITY * span:
            return ("range", (lo_id, hi_id, span_ids))
        return ("ids", span_ids)

    # -------------------------------------------------------- observability
    # per-op latency histograms emitted by ShardedTable / LSMRuns, keyed by
    # the metric-catalog op names (src/repro/db/README.md "Observability")
    _METRIC_OPS = ("ingest", "query", "scan", "flush", "major_compaction")

    def metrics(self) -> dict:
        """Aggregated observability snapshot of every live bound table:
        per-shard and per-table counters, per-op latency percentiles, WAL
        append/fsync totals, plus a cross-table aggregate. JSON-ready."""
        reg = default_registry()

        def pooled(name, tables, **extra):
            h = Histogram(reg, name, {})
            for t in tables:
                key = "table" if not name.startswith("wal_") else "log"
                for inst in reg.series(name, **{key: t}, **extra):
                    h.merge(inst)
            return h.snapshot()

        def ctr_sum(name, tables, **extra):
            key = "table" if not name.startswith("wal_") else "log"
            return sum(sum(c.value for c in reg.series(name, **{key: t},
                                                       **extra))
                       for t in tables)

        live = [n for n, t in self.tables.items()
                if getattr(t, "store", None) is not None
                and not t.store._closed]
        out = {"instance": self.instance, "num_shards": self.num_shards,
               "tables": {}, "aggregate": {}}
        for name in live:
            store = self.tables[name].store
            tbl = {"engine": store.engine,
                   "counters": store.engine_stats(),
                   "latency_s": {op: pooled("db_op_latency_s", [name], op=op)
                                 for op in self._METRIC_OPS},
                   "wal": {
                       "appends": ctr_sum("wal_appends", [name]),
                       "append_bytes": ctr_sum("wal_append_bytes", [name]),
                       "fsyncs": ctr_sum("wal_fsyncs", [name]),
                       "replay_batches": ctr_sum("wal_replay_batches",
                                                 [name]),
                       "append_s": pooled("wal_latency_s", [name],
                                          op="append"),
                       "fsync_s": pooled("wal_latency_s", [name],
                                         op="fsync"),
                   },
                   "shards": {}}
            for s in range(store.S):
                tbl["shards"][str(s)] = {
                    "ingest_entries": ctr_sum("db_ingest_entries", [name],
                                              shard=s),
                    "point_queries": ctr_sum("db_point_queries", [name],
                                             shard=s),
                    "range_scans": ctr_sum("db_range_scans", [name],
                                           shard=s),
                    "flushes": ctr_sum("lsm_shard_flushes", [name], shard=s),
                    "compactions": ctr_sum("lsm_shard_compactions", [name],
                                           shard=s),
                    "query_s": pooled("db_shard_op_latency_s", [name],
                                      shard=s, op="query"),
                    "scan_s": pooled("db_shard_op_latency_s", [name],
                                     shard=s, op="scan"),
                }
            out["tables"][name] = tbl
        agg_counters: dict = {}
        for name in live:
            for k, v in out["tables"][name]["counters"].items():
                if isinstance(v, (int, float)):
                    agg_counters[k] = agg_counters.get(k, 0) + v
        out["aggregate"] = {
            "counters": agg_counters,
            "latency_s": {op: pooled("db_op_latency_s", live, op=op)
                          for op in self._METRIC_OPS},
            "wal": {"appends": ctr_sum("wal_appends", live),
                    "append_bytes": ctr_sum("wal_append_bytes", live),
                    "fsyncs": ctr_sum("wal_fsyncs", live),
                    "fsync_s": pooled("wal_latency_s", live, op="fsync")},
        }
        return out

    def dump_metrics(self, path: str) -> dict:
        """Write ``metrics()`` to ``path`` as JSON; returns the snapshot."""
        snap = self.metrics()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return snap


class Table:
    """A bound table: ingest Assocs/triples, query with Assoc syntax."""

    def __init__(self, server: DBserver, name: str, combiner: str = "last"):
        self.server = server
        self.name = name
        wal_dir = (os.path.join(server.wal_root, name)
                   if getattr(server, "wal_root", None) else None)
        self.store = ShardedTable(
            name, num_shards=server.num_shards,
            capacity_per_shard=server.capacity_per_shard,
            batch_cap=server.batch_cap, id_capacity=server.id_capacity,
            combiner=combiner, use_pallas=server.use_pallas,
            engine=getattr(server, "engine", "lsm"),
            fused_reads=getattr(server, "fused_reads", True),
            fused_q_limit=getattr(server, "fused_q_limit", 512),
            l0_slots=getattr(server, "l0_slots", 4),
            fanout=getattr(server, "fanout", 4),
            wal_dir=wal_dir)
        self.valdict: Optional[StringDict] = None  # set on first string put
        self._valdict_journal: Optional[_DictJournal] = None
        self._deleted = False

    @classmethod
    def _from_store(cls, server: DBserver, name: str, store: ShardedTable,
                    valdict: Optional[StringDict] = None) -> "Table":
        """Bind a recovered store (recover_connector) without creating a
        fresh one; registers the table on the server."""
        t = object.__new__(cls)
        t.server = server
        t.name = name
        t.store = store
        t.valdict = valdict
        t._valdict_journal = None
        t._deleted = False
        if valdict is not None and store._wal_dir is not None:
            t._valdict_journal = _DictJournal(store._wal_dir, "valdict")
        server.tables[name] = t
        return t

    def checkpoint(self) -> str:
        """Durability point: snapshot the store's runs AND the string
        dictionaries, so ``recover_connector`` restores string-keyed
        queries — not just the encoded int store. Returns the manifest
        path."""
        self._check_live()
        path = self.store.checkpoint()
        self.server.checkpoint_keydict()
        if self._valdict_journal is not None and self.valdict is not None:
            self._valdict_journal.checkpoint(self.valdict)
        return path

    def _check_live(self) -> None:
        if self._deleted:
            raise RuntimeError(
                f"table {self.name!r} was deleted; re-bind via DB[name]")

    def _mark_deleted(self) -> None:
        """delete(): free the store's buffers and poison this handle."""
        self._deleted = True
        self.store.close()

    def nnz(self) -> int:
        self._check_live()
        return self.store.nnz()

    # -------------------------------------------------------------- ingest
    def put(self, a: Assoc) -> None:
        r, c, v = a.triples()
        self.put_triple(r, c, v)

    def put_triple(self, rows, cols, vals) -> None:
        self._check_live()
        rows = np.asarray(rows, dtype=object)
        cols = np.asarray(cols, dtype=object)
        vals = np.asarray(vals)
        for br, bc, bv in batching.batch_triples(rows, cols, vals,
                                                 self.server.char_budget):
            rid = self.server.encode_keys(br)
            cid = self.server.encode_keys(bc)
            if bv.dtype.kind in "OUS":
                if self.valdict is None:
                    self.valdict = StringDict()
                    if self.store._wal_dir is not None:
                        self._valdict_journal = _DictJournal(
                            self.store._wal_dir, "valdict")
                before = len(self.valdict)
                val = self.valdict.encode(bv.astype(object)).astype(np.float32) + 1.0
                if (self._valdict_journal is not None
                        and len(self.valdict) > before):
                    self._valdict_journal.append(
                        self.valdict._to_str[before:])
            else:
                val = bv.astype(np.float32)
            self.store.insert(rid, cid, val)

    putTriple = put_triple

    # --------------------------------------------------------------- query
    def _assemble(self, rid, cid, val) -> Assoc:
        if len(rid) == 0:
            return Assoc()
        rows = self.server.keydict.decode(rid)
        cols = self.server.keydict.decode(cid)
        if self.valdict is not None:
            vals = self.valdict.decode(val.astype(np.int64) - 1)
        else:
            vals = val.astype(np.float64)
        return Assoc(rows, cols, vals)

    def __getitem__(self, key) -> Assoc:
        self._check_live()
        rsel, csel = key
        kind, arg = self.server.resolve_selector_plan(rsel)
        cids = self.server.resolve_selector(csel)
        if kind == "all":  # full scan (optionally filtered by column)
            r, c, v = self.store.scan()
        elif kind == "range":  # contiguous rows: ONE scan per shard, no
            lo, hi, filt = arg  # id-list point expansion
            r, c, v = self.store.scan_range(lo, hi)
            if filt is not None:  # dense superset: drop dict-absent ids
                keep = np.isin(r, filt)
                r, c, v = r[keep], c[keep], v[keep]
        else:
            r, c, v = self.store.query_rows(arg)
        if cids is not None:  # single tables filter columns client-side;
            keep = np.isin(c, cids)  # TablePair routes to the transpose table
            r, c, v = r[keep], c[keep], v[keep]
        return self._assemble(r, c, v)


class TablePair:
    """Edge table + its transpose; column queries auto-route to the
    transpose table 'for speed' (paper §III-B)."""

    def __init__(self, table: Table, table_t: Table):
        self.table = table
        self.table_t = table_t

    @property
    def name(self) -> str:
        return self.table.name

    def nnz(self) -> int:
        return self.table.nnz()

    def put(self, a: Assoc) -> None:
        self.table.put(a)
        self.table_t.put(a.transpose())

    def put_triple(self, rows, cols, vals) -> None:
        self.table.put_triple(rows, cols, vals)
        self.table_t.put_triple(cols, rows, vals)

    putTriple = put_triple

    def __getitem__(self, key) -> Assoc:
        rsel, csel = key
        row_all = rsel is None or rsel == ":" or (
            isinstance(rsel, slice) and rsel == slice(None))
        if row_all and csel is not None:
            return self.table_t[csel, rsel].transpose()  # transpose routing
        return self.table[rsel, csel]


def put(table, a: Assoc) -> None:
    table.put(a)


def putTriple(table, rows, cols, vals) -> None:
    table.put_triple(rows, cols, vals)


def recover_connector(wal_root: str, name: str,
                      instance: str = "recovered"):
    """Rebuild a connector-level (string-keyed) table after a crash.

    Loads the shared key dictionary (checkpoint snapshot + journal suffix)
    and the table's value dictionary from ``wal_root``, recovers the
    encoded store via ``db.lsm.recover``, and binds a live ``Table`` on a
    fresh ``DBserver`` — so ``T["a,", :]`` works again, not just raw id
    queries. Returns ``(server, table)``; both keep journaling to the same
    ``wal_root``.
    """
    from .lsm.manifest import MANIFEST
    from .lsm.manifest import recover as recover_store

    table_dir = os.path.join(wal_root, name)
    with open(os.path.join(table_dir, MANIFEST)) as f:
        man = json.load(f)
    cfg = man["config"]
    server = DBserver(instance, num_shards=cfg["num_shards"],
                      capacity_per_shard=cfg["capacity_per_shard"],
                      batch_cap=cfg["batch_cap"],
                      id_capacity=cfg["id_capacity"],
                      use_pallas=cfg["use_pallas"], engine="lsm")
    # dictionary state must load BEFORE the journal re-opens for append
    server.keydict = _load_dict(wal_root, "keydict")
    server.attach_wal_root(wal_root)
    store = recover_store(table_dir)
    valdict = None
    if any(os.path.exists(p) for p in _dict_paths(table_dir, "valdict")):
        valdict = _load_dict(table_dir, "valdict")
        if len(valdict) == 0:
            valdict = None
    table = Table._from_store(server, name, store, valdict)
    return server, table


def delete(table) -> None:
    """Drop a table (or pair) from its server AND release its storage.

    The bound handle is poisoned: subsequent put/__getitem__/nnz raise
    RuntimeError instead of silently operating on an orphaned store.
    Re-binding the same name via ``DB[name]`` creates a fresh table.
    """
    if isinstance(table, TablePair):
        delete(table.table)
        delete(table.table_t)
        return
    table.server.drop(table.name)
    table._mark_deleted()
