"""The D4M.jl database connector API — paper Listing 1, verbatim workflow:

    dbinit()
    DB = dbsetup("mydb02", "db.conf")
    Tedge = DB["my_Tedge", "my_TedgeT"]     # table pair (auto-transpose)
    TedgeDeg = DB["my_TedgeDeg"]
    put(Tedge, A)
    Arow = Tedge["e1,", :]
    Acol = Tedge[:, "v1,"]
    delete(Tedge); delete(TedgeDeg)

The paper's contribution is hiding JavaCall/JVM friction behind this API;
our adaptation hides dictionary-encoding, fixed-capacity padding, and mesh
sharding behind the *same* API (DESIGN §2).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import numpy as np

from ..core.assoc import Assoc, split_str
from ..core.dictionary import StringDict
from . import batching
from .kvstore import ShardedTable

_INITIALIZED = False


def dbinit() -> None:
    """JVM-init analogue: warm the device runtime once per process."""
    global _INITIALIZED
    if not _INITIALIZED:
        import jax
        jax.devices()  # touch the backend
        _INITIALIZED = True


def dbsetup(instance: str, conf: Optional[dict] = None, **kw) -> "DBserver":
    """Create a server binding (conf dict stands in for db.conf)."""
    dbinit()
    cfg = dict(conf or {})
    cfg.update(kw)
    return DBserver(instance, **cfg)


class DBserver:
    """Connection holder; indexing binds tables (creating them on demand)."""

    def __init__(self, instance: str, num_shards: int = 4,
                 capacity_per_shard: int = 1 << 18, batch_cap: int = 1 << 15,
                 id_capacity: int = 1 << 22,
                 char_budget: int = batching.DEFAULT_CHAR_BUDGET,
                 use_pallas: bool = False,  # True = TPU kernels (interpret
                 # mode on CPU is validation-only; XLA path is the CPU path)
                 engine: str = "lsm"):  # storage engine: "lsm" (leveled
                 # runs, db/lsm) or "single" (legacy one-run tablet)
        assert num_shards * id_capacity < 2 ** 31, "id space must fit int32 routing"
        self.instance = instance
        self.num_shards = num_shards
        self.capacity_per_shard = capacity_per_shard
        self.batch_cap = batch_cap
        self.id_capacity = id_capacity
        self.char_budget = char_budget
        self.use_pallas = use_pallas
        self.engine = engine
        self.keydict = StringDict()          # shared row/col key universe
        self._sorted_keys: Optional[np.ndarray] = None
        self.tables: dict = {}

    # ------------------------------------------------------------- binding
    def __getitem__(self, names: Union[str, Tuple[str, str]]):
        if isinstance(names, tuple):
            t, tt = names
            return TablePair(self._bind(t), self._bind(tt))
        return self._bind(names)

    def _bind(self, name: str) -> "Table":
        if name not in self.tables:
            self.tables[name] = Table(self, name)
        return self.tables[name]

    def ls(self):
        return sorted(self.tables)

    def drop(self, name: str) -> None:
        self.tables.pop(name, None)

    # ----------------------------------------------------- key resolution
    def encode_keys(self, strs: np.ndarray) -> np.ndarray:
        ids = self.keydict.encode(strs)
        if ids.size and ids.max() >= self.id_capacity:
            raise OverflowError("key universe exceeded id_capacity")
        self._sorted_keys = None  # invalidate range-query snapshot
        return ids

    def _snapshot(self):
        if self._sorted_keys is None or len(self._sorted_keys) != len(self.keydict):
            keys = self.keydict.decode(np.arange(len(self.keydict)))
            order = np.argsort(keys)
            self._sorted_keys = keys[order]
            self._sorted_ids = np.arange(len(keys), dtype=np.int32)[order]
        return self._sorted_keys, self._sorted_ids

    def resolve_selector(self, sel) -> Optional[np.ndarray]:
        """D4M selector -> row ids; None means 'all' (full scan).

        Accumulo scans string ranges server-side; the adaptation expands
        range/prefix selectors to id lists via the key dictionary (it knows
        the whole key universe), then issues batched point queries.
        """
        if sel is None or sel == ":" or (isinstance(sel, slice) and sel == slice(None)):
            return None
        toks = split_str(sel) if isinstance(sel, str) else np.asarray(
            [str(t) for t in np.asarray(sel).ravel()], dtype=object)
        skeys, sids = self._snapshot()
        if len(toks) == 3 and toks[1] == ":":
            lo = np.searchsorted(skeys, toks[0], side="left")
            hi = np.searchsorted(skeys, toks[2], side="right")
            return np.sort(sids[lo:hi])
        out = []
        for t in toks:
            if t.endswith("*"):
                pre = t[:-1]
                lo = np.searchsorted(skeys, pre, side="left")
                hi = np.searchsorted(skeys, pre + "￿", side="right")
                out.append(sids[lo:hi])
            else:
                i = self.keydict.get(t)
                if i >= 0:
                    out.append(np.asarray([i], dtype=np.int32))
        if not out:
            return np.zeros(0, dtype=np.int32)
        return np.unique(np.concatenate(out))


class Table:
    """A bound table: ingest Assocs/triples, query with Assoc syntax."""

    def __init__(self, server: DBserver, name: str, combiner: str = "last"):
        self.server = server
        self.name = name
        self.store = ShardedTable(
            name, num_shards=server.num_shards,
            capacity_per_shard=server.capacity_per_shard,
            batch_cap=server.batch_cap, id_capacity=server.id_capacity,
            combiner=combiner, use_pallas=server.use_pallas,
            engine=getattr(server, "engine", "lsm"))
        self.valdict: Optional[StringDict] = None  # set on first string put
        self._deleted = False

    def _check_live(self) -> None:
        if self._deleted:
            raise RuntimeError(
                f"table {self.name!r} was deleted; re-bind via DB[name]")

    def _mark_deleted(self) -> None:
        """delete(): free the store's buffers and poison this handle."""
        self._deleted = True
        self.store.close()

    def nnz(self) -> int:
        self._check_live()
        return self.store.nnz()

    # -------------------------------------------------------------- ingest
    def put(self, a: Assoc) -> None:
        r, c, v = a.triples()
        self.put_triple(r, c, v)

    def put_triple(self, rows, cols, vals) -> None:
        self._check_live()
        rows = np.asarray(rows, dtype=object)
        cols = np.asarray(cols, dtype=object)
        vals = np.asarray(vals)
        for br, bc, bv in batching.batch_triples(rows, cols, vals,
                                                 self.server.char_budget):
            rid = self.server.encode_keys(br)
            cid = self.server.encode_keys(bc)
            if bv.dtype.kind in "OUS":
                if self.valdict is None:
                    self.valdict = StringDict()
                val = self.valdict.encode(bv.astype(object)).astype(np.float32) + 1.0
            else:
                val = bv.astype(np.float32)
            self.store.insert(rid, cid, val)

    putTriple = put_triple

    # --------------------------------------------------------------- query
    def _assemble(self, rid, cid, val) -> Assoc:
        if len(rid) == 0:
            return Assoc()
        rows = self.server.keydict.decode(rid)
        cols = self.server.keydict.decode(cid)
        if self.valdict is not None:
            vals = self.valdict.decode(val.astype(np.int64) - 1)
        else:
            vals = val.astype(np.float64)
        return Assoc(rows, cols, vals)

    def __getitem__(self, key) -> Assoc:
        self._check_live()
        rsel, csel = key
        rids = self.server.resolve_selector(rsel)
        cids = self.server.resolve_selector(csel)
        if rids is None:  # full scan (optionally filtered by column)
            r, c, v = self.store.scan()
        else:
            r, c, v = self.store.query_rows(rids)
        if cids is not None:  # single tables filter columns client-side;
            keep = np.isin(c, cids)  # TablePair routes to the transpose table
            r, c, v = r[keep], c[keep], v[keep]
        return self._assemble(r, c, v)


class TablePair:
    """Edge table + its transpose; column queries auto-route to the
    transpose table 'for speed' (paper §III-B)."""

    def __init__(self, table: Table, table_t: Table):
        self.table = table
        self.table_t = table_t

    @property
    def name(self) -> str:
        return self.table.name

    def nnz(self) -> int:
        return self.table.nnz()

    def put(self, a: Assoc) -> None:
        self.table.put(a)
        self.table_t.put(a.transpose())

    def put_triple(self, rows, cols, vals) -> None:
        self.table.put_triple(rows, cols, vals)
        self.table_t.put_triple(cols, rows, vals)

    putTriple = put_triple

    def __getitem__(self, key) -> Assoc:
        rsel, csel = key
        row_all = rsel is None or rsel == ":" or (
            isinstance(rsel, slice) and rsel == slice(None))
        if row_all and csel is not None:
            return self.table_t[csel, rsel].transpose()  # transpose routing
        return self.table[rsel, csel]


def put(table, a: Assoc) -> None:
    table.put(a)


def putTriple(table, rows, cols, vals) -> None:
    table.put_triple(rows, cols, vals)


def delete(table) -> None:
    """Drop a table (or pair) from its server AND release its storage.

    The bound handle is poisoned: subsequent put/__getitem__/nnz raise
    RuntimeError instead of silently operating on an orphaned store.
    Re-binding the same name via ``DB[name]`` creates a fresh table.
    """
    if isinstance(table, TablePair):
        delete(table.table)
        delete(table.table_t)
        return
    table.server.drop(table.name)
    table._mark_deleted()
