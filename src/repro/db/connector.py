"""The D4M.jl database connector API — paper Listing 1, verbatim workflow:

    dbinit()
    DB = dbsetup("mydb02", "db.conf")
    Tedge = DB["my_Tedge", "my_TedgeT"]     # table pair (auto-transpose)
    TedgeDeg = DB["my_TedgeDeg"]
    put(Tedge, A)
    Arow = Tedge["e1,", :]
    Acol = Tedge[:, "v1,"]
    delete(Tedge); delete(TedgeDeg)

The paper's contribution is hiding JavaCall/JVM friction behind this API;
our adaptation hides dictionary-encoding, fixed-capacity padding, and mesh
sharding behind the *same* API (DESIGN §2).

Binding a pair creates ONE engine-maintained transpose pair: ``put`` lands
each batch in ``A`` and ``A^T`` behind a single pair-tagged WAL record
(one fsync — crash recovery replays both sides or neither, so the pair
can never diverge), and ``Tedge[:, "v1,"]`` compiles to a fence-bracketed
range scan on the transpose sibling instead of an O(nnz)
full-scan-and-filter. Selectors compile to ``ReadPlan`` values
(``resolve_selector_plan``) that record axis, kind, and routing for both
the row and column dimension.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Optional, Tuple, Union

import numpy as np

from ..core.assoc import Assoc, split_str
from ..core.dictionary import StringDict
from ..obs import Histogram, default_registry, default_tracer
from ..obs import span as obs_span
from ..obs.export import registry_from_snapshot, write_debug_bundle
from . import batching
from .kvstore import ShardedTable, StoreConfig

_INITIALIZED = False


def _sel_is_all(sel) -> bool:
    """Is this selector the unconstrained axis (``:`` / ``None`` /
    ``slice(None)``)? The ONE place this check lives — every consumer
    goes through ``resolve_selector_plan``."""
    if sel is None:
        return True
    if isinstance(sel, str):
        return sel == ":"
    return isinstance(sel, slice) and sel == slice(None)


@dataclasses.dataclass(frozen=True)
class ReadPlan:
    """A compiled selector for ONE axis of a D4M read.

    ``resolve_selector_plan`` produces these for rows AND columns alike —
    the selector algebra is axis-symmetric; only the *execution* differs
    (``route``): a column plan executes natively as a residual filter on
    a row-driven read, or routes to the transpose sibling's fused scan
    when the store maintains one.

    Fields (unused ones stay None):

    * ``axis``  — "row" | "col": which axis the selector constrains
    * ``kind``  — "all" (unconstrained), "ids" (point id set), or
      "range" (contiguous id range [lo, hi))
    * ``ids``   — kind="ids": sorted unique int32 ids to point-query
    * ``lo, hi``— kind="range": the id range endpoints
    * ``filter``— kind="range" with dict-absent holes: the sorted id
      subset actually selected (scan the dense superset, keep these)
    * ``route`` — "native" | "transpose": set at execution time
    """
    axis: str = "row"
    kind: str = "all"
    ids: Optional[np.ndarray] = None
    lo: Optional[int] = None
    hi: Optional[int] = None
    filter: Optional[np.ndarray] = None
    route: str = "native"

    def with_route(self, route: str) -> "ReadPlan":
        return dataclasses.replace(self, route=route)

    def filter_ids(self) -> Optional[np.ndarray]:
        """The id set this plan keeps (for residual-filter use): ``ids``
        for point plans, ``filter`` (or the dense [lo, hi) range) for
        range plans, None for "all" (keeps everything)."""
        if self.kind == "all":
            return None
        if self.kind == "ids":
            return self.ids
        return (self.filter if self.filter is not None
                else np.arange(self.lo, self.hi, dtype=np.int32))


# ---------------------------------------------------------------------------
# String-dictionary durability (ROADMAP "dictionary durability"): the WAL
# journals encoded int triples, so recovering *string-keyed* queries needs
# the dictionaries too. Each dict persists as a checkpoint snapshot
# (<stem>.json, the whole id->string list) plus an append-only journal
# (<stem>.log, one JSON line per newly interned string, flushed before the
# triple batch that uses those ids reaches the triple WAL). Recovery loads
# the snapshot and replays the journal suffix; a torn last line is
# discarded — its ids can never appear in the triple WAL, which is always
# flushed after the dict journal.
# ---------------------------------------------------------------------------
def _dict_paths(dirpath: str, stem: str) -> Tuple[str, str]:
    return (os.path.join(dirpath, stem + ".json"),
            os.path.join(dirpath, stem + ".log"))


def _load_dict(dirpath: str, stem: str) -> StringDict:
    """Rebuild a StringDict from its checkpoint + journal suffix."""
    jpath, lpath = _dict_paths(dirpath, stem)
    strs = []
    if os.path.exists(jpath):
        with open(jpath) as f:
            strs = json.load(f)
    seen = set(strs)
    if os.path.exists(lpath):
        with open(lpath, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    s = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail from a crash mid-append
                # a crash BETWEEN checkpoint's snapshot write and its
                # journal reset leaves journal lines the snapshot already
                # holds; appends are strictly-new strings in id order, so
                # membership dedup restores the exact id positions
                if s not in seen:
                    strs.append(s)
                    seen.add(s)
    return StringDict.from_strings(strs)


class _DictJournal:
    """Open append handle for one dictionary's .log file."""

    def __init__(self, dirpath: str, stem: str):
        self.jpath, self.lpath = _dict_paths(dirpath, stem)
        self._f = open(self.lpath, "a", encoding="utf-8")

    def append(self, strings) -> None:
        for s in strings:
            self._f.write(json.dumps(s) + "\n")
        self._f.flush()

    def checkpoint(self, d: StringDict) -> None:
        """Snapshot the whole dict and reset the journal (compaction)."""
        d.save(self.jpath)
        self._f.close()
        self._f = open(self.lpath, "w", encoding="utf-8")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def dbinit() -> None:
    """JVM-init analogue: warm the device runtime once per process."""
    global _INITIALIZED
    if not _INITIALIZED:
        import jax
        jax.devices()  # touch the backend
        _INITIALIZED = True


def dbsetup(instance: str, conf: Optional[dict] = None, **kw) -> "DBserver":
    """Create a server binding (conf dict stands in for db.conf).

    The engine/topology keys of ``conf`` build ONE ``StoreConfig`` here;
    every table the server binds shares that record by reference (no
    per-layer kwargs relay), and checkpoints round-trip it through the
    snapshot manifest."""
    dbinit()
    cfg = dict(conf or {})
    cfg.update(kw)
    char_budget = cfg.pop("char_budget", batching.DEFAULT_CHAR_BUDGET)
    wal_root = cfg.pop("wal_root", None)
    config = cfg.pop("config", None)
    if config is None:
        config = StoreConfig(**cfg)
    elif cfg:
        config = config.replace(**cfg)
    return DBserver(instance, config=config, char_budget=char_budget,
                    wal_root=wal_root)


class DBserver:
    """Connection holder; indexing binds tables (creating them on demand).

    ``config`` (a ``kvstore.StoreConfig``) is the single source of truth
    for engine/topology settings; the legacy per-field attributes
    (``num_shards``, ``engine``, ...) are read-only views of it. Extra
    keyword arguments override config fields (``DBserver("x",
    num_shards=8)`` still works)."""

    def __init__(self, instance: str, config: StoreConfig = None,
                 char_budget: int = batching.DEFAULT_CHAR_BUDGET,
                 wal_root: str = None,  # durability root: each table logs
                 # to <wal_root>/<table>/, the shared key dictionary to
                 # <wal_root>/keydict.{json,log}
                 **kw):
        cfg = config if config is not None else StoreConfig()
        if kw:
            cfg = cfg.replace(**kw)  # unknown keys raise, as before
        assert cfg.num_shards * cfg.id_capacity < 2 ** 31, \
            "id space must fit int32 routing"
        self.instance = instance
        self.config = cfg
        self.char_budget = char_budget
        self.keydict = StringDict()          # shared row/col key universe
        self._sorted_keys: Optional[np.ndarray] = None
        self.tables: dict = {}
        self.wal_root: Optional[str] = None
        self._keydict_journal: Optional[_DictJournal] = None
        self._peer_snapshots: list = []  # other processes' registry dumps
        if wal_root is not None:
            self.attach_wal_root(wal_root)

    # read-only views of the shared StoreConfig (legacy attribute API)
    num_shards = property(lambda self: self.config.num_shards)
    capacity_per_shard = property(lambda self: self.config.capacity_per_shard)
    batch_cap = property(lambda self: self.config.batch_cap)
    id_capacity = property(lambda self: self.config.id_capacity)
    use_pallas = property(lambda self: self.config.use_pallas)
    engine = property(lambda self: self.config.engine)
    fused_reads = property(lambda self: self.config.fused_reads)
    fused_q_limit = property(lambda self: self.config.fused_q_limit)
    l0_slots = property(lambda self: self.config.l0_slots)
    fanout = property(lambda self: self.config.fanout)

    def attach_wal_root(self, wal_root: str) -> None:
        """Enable durability under ``wal_root``. Call AFTER loading any
        pre-existing dictionary state (recover_connector does)."""
        os.makedirs(wal_root, exist_ok=True)
        if self._keydict_journal is not None:
            self._keydict_journal.close()
        self.wal_root = wal_root
        self._keydict_journal = _DictJournal(wal_root, "keydict")

    # ------------------------------------------------------------- binding
    def __getitem__(self, names: Union[str, Tuple[str, str]]):
        if isinstance(names, tuple):
            t, tt = names
            return self._bind_pair(t, tt)
        return self._bind(names)

    def _bind(self, name: str) -> "Table":
        if name not in self.tables:
            self.tables[name] = Table(self, name)
        return self.tables[name]

    def _bind_pair(self, t: str, tt: str) -> "TablePair":
        """Bind ``DB[t, tt]``: ONE transpose-enabled store (the engine
        maintains ``A^T`` as a sibling shard set behind the same WAL),
        with ``tt`` bound as a read-facing transposed view of it."""
        tbl = self.tables.get(t)
        if tbl is None:
            tbl = Table(self, t, transpose=True)
            self.tables[t] = tbl
        elif getattr(getattr(tbl, "store", None), "t_store", None) is None:
            raise ValueError(
                f"table {t!r} is already bound without a transpose "
                "sibling; delete it before re-binding as a pair")
        view = self.tables.get(tt)
        if not isinstance(view, TransposedView):
            view = TransposedView(tbl, tt)
            self.tables[tt] = view
        return TablePair(tbl, view)

    def ls(self):
        return sorted(self.tables)

    def drop(self, name: str) -> None:
        """Unbind a table AND release its store buffers (the old pop-only
        drop leaked device memtables and the open WAL handle)."""
        t = self.tables.pop(name, None)
        if isinstance(t, Table) and not t._deleted:
            t._mark_deleted()

    # ----------------------------------------------------- key resolution
    def encode_keys(self, strs: np.ndarray) -> np.ndarray:
        before = len(self.keydict)
        ids = self.keydict.encode(strs)
        if ids.size and ids.max() >= self.id_capacity:
            raise OverflowError("key universe exceeded id_capacity")
        if self._keydict_journal is not None and len(self.keydict) > before:
            # journal newly interned strings (in id order) BEFORE any
            # triple using those ids can reach a table WAL
            self._keydict_journal.append(self.keydict._to_str[before:])
        self._sorted_keys = None  # invalidate range-query snapshot
        return ids

    def checkpoint_keydict(self) -> None:
        """Snapshot the shared key dictionary + reset its journal."""
        if self._keydict_journal is None:
            raise ValueError("checkpoint_keydict() needs a wal_root")
        self._keydict_journal.checkpoint(self.keydict)

    def _snapshot(self):
        if self._sorted_keys is None or len(self._sorted_keys) != len(self.keydict):
            keys = self.keydict.decode(np.arange(len(self.keydict)))
            order = np.argsort(keys)
            self._sorted_keys = keys[order]
            self._sorted_ids = np.arange(len(keys), dtype=np.int32)[order]
        return self._sorted_keys, self._sorted_ids

    def _span_ids(self, lo_key: str, hi_key: str) -> np.ndarray:
        """Sorted dict ids of every key in the STRING range
        [lo_key, hi_key] (both inclusive — the one searchsorted span both
        the range and prefix selectors reduce to, shared by the id-list
        and scan-plan resolvers so they can never disagree)."""
        skeys, sids = self._snapshot()
        lo = np.searchsorted(skeys, lo_key, side="left")
        hi = np.searchsorted(skeys, hi_key, side="right")
        return np.sort(sids[lo:hi]).astype(np.int32)

    def _point_ids(self, toks) -> np.ndarray:
        """Expand explicit key tokens (and ``prefix*`` tokens) to the
        sorted unique id set present in the dictionary."""
        out = []
        for t in toks:
            if t.endswith("*"):
                out.append(self._span_ids(t[:-1], t[:-1] + "￿"))
            else:
                i = self.keydict.get(t)
                if i >= 0:
                    out.append(np.asarray([i], dtype=np.int32))
        if not out:
            return np.zeros(0, dtype=np.int32)
        return np.unique(np.concatenate(out))

    def resolve_selector(self, sel) -> Optional[np.ndarray]:
        """Deprecated: D4M selector -> id list (None means 'all').

        Thin shim over ``resolve_selector_plan`` kept for callers that
        still want a materialized id set; new code should consume the
        ``ReadPlan`` directly (range plans there scan without expanding
        to O(range) point ids)."""
        warnings.warn(
            "resolve_selector() is deprecated; use resolve_selector_plan()"
            " and consume the ReadPlan", DeprecationWarning, stacklevel=2)
        return self.resolve_selector_plan(sel).filter_ids()

    # a dict-range id set denser than this scans the covering id range in
    # one fused dispatch and filters the stragglers on the host; sparser
    # sets fall back to batched point queries
    RANGE_SCAN_DENSITY = 0.5

    def resolve_selector_plan(self, sel, axis: str = "row") -> ReadPlan:
        """D4M selector -> ``ReadPlan``, WITHOUT materializing an id list
        when a server-side range scan can serve it (Accumulo scans string
        ranges tablet-side; ``T["a,:,c,", :]`` should not expand to
        O(range) point queries).

        The plan's ``kind`` is "all" (unconstrained axis), "ids" (point
        queries over an explicit id set), or "range" ([lo, hi) id-range
        scan, with ``filter`` carrying the dict-present subset when the
        string range has id holes denser than ``RANGE_SCAN_DENSITY``).

        The SAME compilation serves both axes (rows and columns share one
        key dictionary): ``axis="col"`` plans route to the transpose
        sibling's fused scan on pair tables, or execute as residual
        filters pushed into the row-driven dispatch otherwise.

        Range/prefix selectors map through the key dictionary's sorted-key
        snapshot: the matching ids are contiguous whenever keys were
        interned in lexicographic order (sorted ingest, the common D4M
        bulk-load shape) — then the scan needs no id list at all.
        """
        if _sel_is_all(sel):
            return ReadPlan(axis=axis, kind="all")
        toks = split_str(sel) if isinstance(sel, str) else np.asarray(
            [str(t) for t in np.asarray(sel).ravel()], dtype=object)
        span_ids = None
        if len(toks) == 3 and toks[1] == ":":
            span_ids = self._span_ids(toks[0], toks[2])
        elif len(toks) == 1 and toks[0].endswith("*"):
            span_ids = self._span_ids(toks[0][:-1], toks[0][:-1] + "￿")
        if span_ids is None:
            return ReadPlan(axis=axis, kind="ids", ids=self._point_ids(toks))
        if len(span_ids) == 0:
            return ReadPlan(axis=axis, kind="ids", ids=span_ids)
        lo_id, hi_id = int(span_ids[0]), int(span_ids[-1]) + 1
        span = hi_id - lo_id
        if span == len(span_ids):
            return ReadPlan(axis=axis, kind="range", lo=lo_id, hi=hi_id)
        if len(span_ids) >= self.RANGE_SCAN_DENSITY * span:
            return ReadPlan(axis=axis, kind="range", lo=lo_id, hi=hi_id,
                            filter=span_ids)
        return ReadPlan(axis=axis, kind="ids", ids=span_ids)

    # -------------------------------------------------------- observability
    # per-op latency histograms emitted by ShardedTable / LSMRuns, keyed by
    # the metric-catalog op names (src/repro/db/README.md "Observability")
    _METRIC_OPS = ("ingest", "query", "scan", "flush", "major_compaction")

    def attach_process_snapshot(self, snapshot) -> None:
        """Register another process's ``Registry.snapshot()`` (the dict,
        or a path to its JSON dump) for ``metrics(all_processes=True)``.
        SPMD launchers dump one registry per process; attaching them here
        lets one connector answer for the whole mesh."""
        if isinstance(snapshot, (str, os.PathLike)):
            with open(snapshot) as f:
                snapshot = json.load(f)
        self._peer_snapshots.append(dict(snapshot))

    def metrics(self, all_processes: bool = False) -> dict:
        """Aggregated observability snapshot of every live bound table:
        per-shard and per-table counters, per-op latency percentiles, WAL
        append/fsync totals, derived health gauges, plus a cross-table
        aggregate. JSON-ready.

        ``all_processes=True`` merges every snapshot registered via
        ``attach_process_snapshot`` into this process's registry view
        (``repro.db.spmd.merge_process_metrics`` semantics: counters sum,
        histograms bucket-merge) before aggregating."""
        for name, t in self.tables.items():
            store = getattr(t, "store", None)
            if store is not None and not store._closed:
                store.refresh_health_gauges()
        reg = default_registry()
        if all_processes and self._peer_snapshots:
            from .spmd import merge_process_metrics
            merged = merge_process_metrics(
                [reg.snapshot()] + self._peer_snapshots)
            reg = registry_from_snapshot(merged)

        def gauge_val(name, **labels):
            insts = reg.series(name, **labels)
            return insts[0].value if insts else 0

        def pooled(name, tables, **extra):
            h = Histogram(reg, name, {})
            for t in tables:
                key = "table" if not name.startswith("wal_") else "log"
                for inst in reg.series(name, **{key: t}, **extra):
                    h.merge(inst)
            return h.snapshot()

        def ctr_sum(name, tables, **extra):
            key = "table" if not name.startswith("wal_") else "log"
            return sum(sum(c.value for c in reg.series(name, **{key: t},
                                                       **extra))
                       for t in tables)

        live = [n for n, t in self.tables.items()
                if getattr(t, "store", None) is not None
                and not t.store._closed]
        out = {"instance": self.instance, "num_shards": self.num_shards,
               "tables": {}, "aggregate": {}}
        for name in live:
            store = self.tables[name].store
            tbl = {"engine": store.engine,
                   "counters": store.engine_stats(),
                   "latency_s": {op: pooled("db_op_latency_s", [name], op=op)
                                 for op in self._METRIC_OPS},
                   "wal": {
                       "appends": ctr_sum("wal_appends", [name]),
                       "append_bytes": ctr_sum("wal_append_bytes", [name]),
                       "fsyncs": ctr_sum("wal_fsyncs", [name]),
                       "replay_batches": ctr_sum("wal_replay_batches",
                                                 [name]),
                       "append_s": pooled("wal_latency_s", [name],
                                          op="append"),
                       "fsync_s": pooled("wal_latency_s", [name],
                                         op="fsync"),
                       "backlog_bytes": gauge_val("wal_backlog_bytes",
                                                  log=name),
                   },
                   "health": {
                       "read_amplification": gauge_val(
                           "lsm_read_amplification", table=name),
                       "write_amplification": gauge_val(
                           "lsm_write_amplification", table=name),
                       "retraces": ctr_sum("lsm_retraces", [name]),
                       "compiled_shapes": sum(
                           g.value for g in
                           reg.series("lsm_compiled_shapes")),
                   },
                   "shards": {}}
            for s in range(store.S):
                tbl["shards"][str(s)] = {
                    "memtable_occupancy": gauge_val(
                        "db_memtable_occupancy", table=name, shard=s),
                    "resident_runs": gauge_val("lsm_resident_runs",
                                               table=name, shard=s),
                    "compaction_debt_entries": gauge_val(
                        "lsm_compaction_debt_entries", table=name, shard=s),
                    "ingest_entries": ctr_sum("db_ingest_entries", [name],
                                              shard=s),
                    "point_queries": ctr_sum("db_point_queries", [name],
                                             shard=s),
                    "range_scans": ctr_sum("db_range_scans", [name],
                                           shard=s),
                    "flushes": ctr_sum("lsm_shard_flushes", [name], shard=s),
                    "compactions": ctr_sum("lsm_shard_compactions", [name],
                                           shard=s),
                    "query_s": pooled("db_shard_op_latency_s", [name],
                                      shard=s, op="query"),
                    "scan_s": pooled("db_shard_op_latency_s", [name],
                                     shard=s, op="scan"),
                }
            if getattr(store, "t_store", None) is not None:
                tbl["transpose"] = {
                    "sibling": store.t_store.name,
                    "counters": store.t_store.engine_stats(),
                }
            tm = getattr(store, "tablet_map", None)
            if tm is not None:
                tbl["tablets"] = {
                    "count": tm.n,
                    "balance": gauge_val("lsm_tablet_balance", table=name),
                    "splits": ctr_sum("lsm_tablet_splits", [name]),
                    "moves": ctr_sum("lsm_tablet_moves", [name]),
                    "owners": [int(o) for o in tm.owners],
                    "boundaries": [int(b) for b in tm.splits],
                }
            out["tables"][name] = tbl
        agg_counters: dict = {}
        for name in live:
            for k, v in out["tables"][name]["counters"].items():
                if isinstance(v, (int, float)):
                    agg_counters[k] = agg_counters.get(k, 0) + v
        out["aggregate"] = {
            "counters": agg_counters,
            "latency_s": {op: pooled("db_op_latency_s", live, op=op)
                          for op in self._METRIC_OPS},
            "wal": {"appends": ctr_sum("wal_appends", live),
                    "append_bytes": ctr_sum("wal_append_bytes", live),
                    "fsyncs": ctr_sum("wal_fsyncs", live),
                    "fsync_s": pooled("wal_latency_s", live, op="fsync")},
        }
        return out

    def dump_metrics(self, path: str) -> dict:
        """Write ``metrics()`` to ``path`` as JSON; returns the snapshot."""
        snap = self.metrics()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return snap

    def debug_bundle(self, path: str, bloom_probes: int = 256) -> str:
        """One-stop diagnostic archive (zip) for a support ticket: raw
        registry snapshot + Prometheus exposition + slow traces / flight
        recordings, plus the store config, each table's resident geometry
        (runs, levels, L0 slots, memtable fill), and the aggregated
        ``metrics()`` view. Health gauges (incl. the bloom fp probe) are
        refreshed first so the bundle is self-consistent. Returns
        ``path``."""
        geometry = {}
        for name, t in self.tables.items():
            store = getattr(t, "store", None)
            if store is None or store._closed:
                continue
            store.refresh_health_gauges(bloom_probes=bloom_probes)
            geo = {"engine": store.engine,
                   "num_shards": store.S,
                   "memtable_cap": store.mem_cap,
                   "memtable_n": [int(x) for x in store._mem_n],
                   "stats": store.engine_stats()}
            if store.engine == "lsm":
                runs = store._runs
                geo["level_caps"] = list(runs.level_caps)
                geo["l0_slots"] = runs.K0
                geo["resident_runs"] = [runs.resident_runs(s)
                                        for s in range(store.S)]
                geo["level_entries_per_shard"] = [
                    [int(n) for n in lv["n"]] for lv in runs.levels]
            geometry[name] = geo
        extra = {
            "store_config": dataclasses.asdict(self.config),
            "resident_geometry": geometry,
            "metrics_view": self.metrics(),
        }
        return write_debug_bundle(path, reg=default_registry(),
                                  tracer=default_tracer(), extra=extra)


class Table:
    """A bound table: ingest Assocs/triples, query with Assoc syntax."""

    def __init__(self, server: DBserver, name: str, combiner: str = "last",
                 transpose: bool = False):
        self.server = server
        self.name = name
        wal_dir = (os.path.join(server.wal_root, name)
                   if getattr(server, "wal_root", None) else None)
        cfg = server.config
        if transpose:
            cfg = cfg.replace(transpose=True)
        self.store = ShardedTable(name, combiner=combiner, wal_dir=wal_dir,
                                  config=cfg)
        self.valdict: Optional[StringDict] = None  # set on first string put
        self._valdict_journal: Optional[_DictJournal] = None
        self._deleted = False

    @classmethod
    def _from_store(cls, server: DBserver, name: str, store: ShardedTable,
                    valdict: Optional[StringDict] = None) -> "Table":
        """Bind a recovered store (recover_connector) without creating a
        fresh one; registers the table on the server."""
        t = object.__new__(cls)
        t.server = server
        t.name = name
        t.store = store
        t.valdict = valdict
        t._valdict_journal = None
        t._deleted = False
        if valdict is not None and store._wal_dir is not None:
            t._valdict_journal = _DictJournal(store._wal_dir, "valdict")
        server.tables[name] = t
        return t

    def checkpoint(self) -> str:
        """Durability point: snapshot the store's runs AND the string
        dictionaries, so ``recover_connector`` restores string-keyed
        queries — not just the encoded int store. Returns the manifest
        path."""
        self._check_live()
        path = self.store.checkpoint()
        self.server.checkpoint_keydict()
        if self._valdict_journal is not None and self.valdict is not None:
            self._valdict_journal.checkpoint(self.valdict)
        return path

    def _check_live(self) -> None:
        if self._deleted:
            raise RuntimeError(
                f"table {self.name!r} was deleted; re-bind via DB[name]")

    def _mark_deleted(self) -> None:
        """delete(): free the store's buffers and poison this handle."""
        if self._deleted:
            return
        self._deleted = True
        self.store.close()

    def nnz(self) -> int:
        self._check_live()
        return self.store.nnz()

    # -------------------------------------------------------------- ingest
    def put(self, a: Assoc) -> None:
        r, c, v = a.triples()
        self.put_triple(r, c, v)

    def put_triple(self, rows, cols, vals) -> None:
        self._check_live()
        rows = np.asarray(rows, dtype=object)
        cols = np.asarray(cols, dtype=object)
        vals = np.asarray(vals)
        # connector-level root span: every batch (dict encode, WAL append,
        # memtable insert, any flush/compaction) shares ONE trace id
        with obs_span("connector.put", table=self.name, n=len(rows)):
            self._put_triple_batches(rows, cols, vals)

    def _put_triple_batches(self, rows, cols, vals) -> None:
        for br, bc, bv in batching.batch_triples(rows, cols, vals,
                                                 self.server.char_budget):
            rid = self.server.encode_keys(br)
            cid = self.server.encode_keys(bc)
            if bv.dtype.kind in "OUS":
                if self.valdict is None:
                    self.valdict = StringDict()
                    if self.store._wal_dir is not None:
                        self._valdict_journal = _DictJournal(
                            self.store._wal_dir, "valdict")
                before = len(self.valdict)
                val = self.valdict.encode(bv.astype(object)).astype(np.float32) + 1.0
                if (self._valdict_journal is not None
                        and len(self.valdict) > before):
                    self._valdict_journal.append(
                        self.valdict._to_str[before:])
            else:
                val = bv.astype(np.float32)
            self.store.insert(rid, cid, val)

    putTriple = put_triple

    # --------------------------------------------------------------- query
    def _assemble(self, rid, cid, val) -> Assoc:
        if len(rid) == 0:
            return Assoc()
        rows = self.server.keydict.decode(rid)
        cols = self.server.keydict.decode(cid)
        if self.valdict is not None:
            vals = self.valdict.decode(val.astype(np.int64) - 1)
        else:
            vals = val.astype(np.float64)
        return Assoc(rows, cols, vals)

    def __getitem__(self, key) -> Assoc:
        self._check_live()
        rsel, csel = key
        rplan = self.server.resolve_selector_plan(rsel, axis="row")
        cplan = self.server.resolve_selector_plan(csel, axis="col")
        r, c, v = self._execute(rplan, cplan)
        return self._assemble(r, c, v)

    def _execute(self, rplan: ReadPlan, cplan: ReadPlan):
        with obs_span("connector.read", table=self.name,
                      row_kind=rplan.kind, col_kind=cplan.kind):
            return self._execute_plans(rplan, cplan)

    def _execute_plans(self, rplan: ReadPlan, cplan: ReadPlan):
        """Run a (row-plan, col-plan) pair against the store.

        Routing rules (db/README.md "Transpose pairs & read planning"):

        * unconstrained rows + constrained cols on a pair table → route
          the column plan to the transpose sibling's fused scan/query
          (the column range is a fence-bracketed ROW range over A^T);
        * otherwise the row plan drives the dispatch and the column
          plan's id set pushes down as an on-device residual filter
          (``col_filter``) inside the fused kernels.
        """
        store = self.store
        if (rplan.kind == "all" and cplan.kind != "all"
                and getattr(store, "t_store", None) is not None):
            cplan = cplan.with_route("transpose")
            if cplan.kind == "range":
                r, c, v = store.scan_col_range(cplan.lo, cplan.hi)
                if cplan.filter is not None:  # dict-absent id holes
                    keep = np.isin(c, cplan.filter)
                    r, c, v = r[keep], c[keep], v[keep]
            else:
                r, c, v = store.query_cols(cplan.ids)
            return r, c, v
        cfilt = cplan.filter_ids()  # pushed into the fused dispatch
        if rplan.kind == "range":  # contiguous rows: ONE scan per shard,
            r, c, v = store.scan_range(rplan.lo, rplan.hi,  # no id-list
                                       col_filter=cfilt)    # expansion
            if rplan.filter is not None:  # dense superset: drop absents
                keep = np.isin(r, rplan.filter)
                r, c, v = r[keep], c[keep], v[keep]
            return r, c, v
        if rplan.kind == "ids":
            return store.query_rows(rplan.ids, col_filter=cfilt)
        r, c, v = store.scan()  # full scan; filter columns client-side
        if cfilt is not None:
            keep = np.isin(c, cfilt)
            r, c, v = r[keep], c[keep], v[keep]
        return r, c, v


class TransposedView:
    """Read/write-facing ``A^T`` binding over a pair table.

    The paper binds ``DB["my_Tedge", "my_TedgeT"]`` as two tables; here
    the second name is a VIEW of the first — the engine already maintains
    the transpose sibling shard set, so the view swaps selectors (and
    transposes results) rather than owning storage. ``store`` is None on
    purpose: server bookkeeping (metrics, live lists) skips views and
    reports the pair once, under the primary's name."""

    store = None

    def __init__(self, table: Table, name: str):
        self.table = table
        self.name = name

    @property
    def _deleted(self) -> bool:
        return self.table._deleted

    def nnz(self) -> int:
        return self.table.nnz()

    def put(self, a: Assoc) -> None:
        self.table.put(a.transpose())

    def put_triple(self, rows, cols, vals) -> None:
        self.table.put_triple(cols, rows, vals)

    putTriple = put_triple

    def __getitem__(self, key) -> Assoc:
        rsel, csel = key
        return self.table[csel, rsel].transpose()


class TablePair:
    """Edge table + its transpose; column queries auto-route to the
    transpose sibling 'for speed' (paper §III-B).

    Since the engine maintains ``A^T`` itself (one pair-tagged WAL frame
    per ``put`` batch — see the module docstring), the pair handle is a
    thin facade: ingest and queries go to the primary table, whose
    ``_execute`` already routes column plans to the sibling."""

    def __init__(self, table: Table, table_t: TransposedView):
        self.table = table
        self.table_t = table_t

    @property
    def name(self) -> str:
        return self.table.name

    @property
    def name_t(self) -> str:
        return self.table_t.name

    def nnz(self) -> int:
        return self.table.nnz()

    def put(self, a: Assoc) -> None:
        self.table.put(a)  # the engine dual-ingests: ONE WAL frame

    def put_triple(self, rows, cols, vals) -> None:
        self.table.put_triple(rows, cols, vals)

    putTriple = put_triple

    def checkpoint(self) -> str:
        """One durability point covers BOTH sides (the sibling's runs ride
        in the same snapshot npz; one atomic replace)."""
        return self.table.checkpoint()

    def metrics(self) -> dict:
        """This pair's slice of ``server.metrics()`` (primary table entry,
        which carries the sibling under ``"transpose"``)."""
        snap = self.table.server.metrics()
        return snap["tables"].get(self.table.name, {})

    def __getitem__(self, key) -> Assoc:
        return self.table[key]


def put(table, a: Assoc) -> None:
    table.put(a)


def putTriple(table, rows, cols, vals) -> None:
    table.put_triple(rows, cols, vals)


def recover_connector(wal_root: str, name,
                      instance: str = "recovered"):
    """Rebuild a connector-level (string-keyed) table after a crash.

    Loads the shared key dictionary (checkpoint snapshot + journal suffix)
    and the table's value dictionary from ``wal_root``, recovers the
    encoded store via ``db.lsm.recover``, and binds a live ``Table`` on a
    fresh ``DBserver`` — so ``T["a,", :]`` works again, not just raw id
    queries. Returns ``(server, table)``; both keep journaling to the same
    ``wal_root``.

    Pass a 2-tuple ``(name, name_t)`` to recover a transpose PAIR: the
    manifest's StoreConfig carries ``transpose=True``, so the recovered
    store rebuilds both sibling shard sets (snapshot + one pair-tagged WAL
    replay) and the result is ``(server, TablePair)`` with ``name_t``
    bound as the transposed view.
    """
    from .lsm.manifest import MANIFEST
    from .lsm.manifest import recover as recover_store

    pair_name = None
    if isinstance(name, tuple):
        name, pair_name = name
    table_dir = os.path.join(wal_root, name)
    with open(os.path.join(table_dir, MANIFEST)) as f:
        man = json.load(f)
    cfg = man["config"]
    server = DBserver(
        instance,
        config=StoreConfig.from_manifest(cfg).replace(engine="lsm",
                                                      transpose=False))
    # dictionary state must load BEFORE the journal re-opens for append
    server.keydict = _load_dict(wal_root, "keydict")
    server.attach_wal_root(wal_root)
    store = recover_store(table_dir)
    valdict = None
    if any(os.path.exists(p) for p in _dict_paths(table_dir, "valdict")):
        valdict = _load_dict(table_dir, "valdict")
        if len(valdict) == 0:
            valdict = None
    table = Table._from_store(server, name, store, valdict)
    if pair_name is not None:
        if store.t_store is None:
            raise ValueError(
                f"table {name!r} was not checkpointed as a transpose pair; "
                "recover it by its single name")
        view = TransposedView(table, pair_name)
        server.tables[pair_name] = view
        return server, TablePair(table, view)
    return server, table


def delete(table) -> None:
    """Drop a table (or pair) from its server AND release its storage.

    The bound handle is poisoned: subsequent put/__getitem__/nnz raise
    RuntimeError instead of silently operating on an orphaned store.
    Re-binding the same name via ``DB[name]`` creates a fresh table.
    Deleting a pair drops BOTH bindings; the sibling shard set is freed
    by the primary store's close (it owns the sibling).
    """
    if isinstance(table, TablePair):
        server = table.table.server
        server.drop(table.table_t.name)  # view: pop only (no store)
        server.drop(table.table.name)    # closes primary + sibling
        return
    table.server.drop(table.name)
    table._mark_deleted()  # idempotent if drop() already closed it
