"""D4M 2.0 schema (paper ref [11]): edge table + transpose + degree table.

The degree table is maintained *at ingest time* by the combiner analogue
(`kvstore.degree_update`), exactly like attaching a summing iterator to
TedgeDeg in Accumulo. Queries use it for planning: find vertices of a given
degree (the paper's Fig. 4 query-selection procedure) and size query buffers.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.assoc import Assoc
from .connector import DBserver, TablePair, delete as _delete
from .kvstore import degree_update


class DegreeTable:
    """Dense out/in-degree accumulator over the server's vertex-id space."""

    def __init__(self, server: DBserver, name: str):
        self.server = server
        self.name = name
        cap = server.id_capacity
        self.out_deg = jnp.zeros((cap,), jnp.float32)
        self.in_deg = jnp.zeros((cap,), jnp.float32)
        server.tables[name] = self

    def update(self, rid: np.ndarray, cid: np.ndarray) -> None:
        ones_r = jnp.ones((len(rid),), jnp.float32)
        self.out_deg = degree_update(self.out_deg, jnp.asarray(rid), ones_r,
                                     use_pallas=False)
        self.in_deg = degree_update(self.in_deg, jnp.asarray(cid),
                                    jnp.ones((len(cid),), jnp.float32),
                                    use_pallas=False)

    def degrees(self, vertices) -> Assoc:
        ids = self.server.resolve_selector_plan(vertices).filter_ids()
        if ids is None:
            ids = np.arange(len(self.server.keydict), dtype=np.int32)
        out = np.asarray(self.out_deg)[ids]
        ind = np.asarray(self.in_deg)[ids]
        keys = self.server.keydict.decode(ids)
        rows = np.concatenate([keys, keys])
        cols = np.asarray(["OutDeg"] * len(ids) + ["InDeg"] * len(ids), object)
        vals = np.concatenate([out, ind])
        return Assoc(rows, cols, vals)

    def vertices_with_degree(self, target: float, kind: str = "out",
                             tol: float = 10 ** 0.5) -> np.ndarray:
        """Vertex names whose degree is within a factor ``tol`` of target
        (the paper buckets query vertices by degree decade)."""
        deg = np.asarray(self.out_deg if kind == "out" else self.in_deg)
        n = len(self.server.keydict)
        deg = deg[:n]
        hit = np.flatnonzero((deg >= target / tol) & (deg < target * tol))
        return self.server.keydict.decode(hit.astype(np.int32))


class EdgeSchema:
    """The full D4M 2.0 bundle: Tedge / TedgeT / TedgeDeg with auto-upkeep."""

    def __init__(self, server: DBserver, base: str):
        self.server = server
        self.pair = server[f"{base}_Tedge", f"{base}_TedgeT"]
        self.deg = DegreeTable(server, f"{base}_TedgeDeg")

    def put(self, a: Assoc) -> None:
        self.put_triple(*a.triples())

    def put_triple(self, rows, cols, vals) -> None:
        self.pair.put_triple(rows, cols, vals)
        rid = self.server.keydict.lookup(np.asarray(rows, object))
        cid = self.server.keydict.lookup(np.asarray(cols, object))
        self.deg.update(rid, cid)

    def __getitem__(self, key) -> Assoc:
        return self.pair[key]

    def nnz(self) -> int:
        return self.pair.nnz()

    def delete(self) -> None:
        _delete(self.pair)
        self.server.drop(self.deg.name)
