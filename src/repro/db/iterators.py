"""Server-side iterator analogues (Accumulo combiners, paper §III/§VI).

Accumulo attaches combiner iterators to tables (the D4M 2.0 degree table
uses a summing combiner). Here an iterator is a *dedup policy applied during
minor compaction* (`kvstore.tablet_insert`) plus, for dense accumulators,
the `degree_update` fused segment-sum. Graphulo-style server-side GraphBLAS
(the paper's future work) maps to `repro.kernels.spmv` applied shard-side.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class IteratorSpec:
    name: str
    combiner: str   # one of kvstore.COMBINERS
    doc: str


VERSIONING = IteratorSpec("versioning", "last",
                          "Accumulo default: latest write wins.")
SUM_COMBINER = IteratorSpec("sum", "sum",
                            "Summing combiner (D4M 2.0 degree tables).")
MIN_COMBINER = IteratorSpec("min", "min", "Min combiner.")
MAX_COMBINER = IteratorSpec("max", "max", "Max combiner.")

BY_NAME = {s.name: s for s in
           (VERSIONING, SUM_COMBINER, MIN_COMBINER, MAX_COMBINER)}
