"""TokenStore: the KV-store-backed training data pipeline."""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..db.kvstore import ShardedTable, shard_of


class TokenStore:
    """Documents stored as (doc_id, position) -> token in a ShardedTable.

    Row id   = doc id (range-partitioned over shards -> documents spread
               across 'tablet servers' like Accumulo rows),
    Col id   = position,
    Value    = token id (float32 payload; exact below 2**24).
    """

    def __init__(self, num_shards: int = 4, capacity_per_shard: int = 1 << 20,
                 max_docs: int = 1 << 16, use_pallas: bool = False):
        self.store = ShardedTable(
            "tokens", num_shards=num_shards,
            capacity_per_shard=capacity_per_shard,
            batch_cap=1 << 16, id_capacity=max_docs, use_pallas=use_pallas)
        self.doc_lens: List[int] = []

    def ingest(self, docs: List[np.ndarray]) -> None:
        for doc in docs:
            doc_id = len(self.doc_lens)
            n = len(doc)
            self.store.insert(
                np.full(n, doc_id, np.int32),
                np.arange(n, dtype=np.int32),
                doc.astype(np.float32),
            )
            self.doc_lens.append(n)

    def num_docs(self) -> int:
        return len(self.doc_lens)

    def get_doc(self, doc_id: int) -> np.ndarray:
        _, pos, tok = self.store.query_rows(
            np.asarray([doc_id], np.int32),
            max_return=max(self.doc_lens[doc_id], 1))
        order = np.argsort(pos)
        return tok[order].astype(np.int32)

    def sample_batch(self, batch: int, seq_len: int,
                     rng: np.random.Generator) -> np.ndarray:
        """[batch, seq_len] token batch via row queries (wraps short docs)."""
        out = np.zeros((batch, seq_len), np.int32)
        docs = rng.integers(0, self.num_docs(), batch)
        for i, d in enumerate(docs):
            toks = self.get_doc(int(d))
            if len(toks) >= seq_len:
                s = rng.integers(0, len(toks) - seq_len + 1)
                out[i] = toks[s:s + seq_len]
            else:
                reps = -(-seq_len // max(len(toks), 1))
                out[i] = np.tile(toks, reps)[:seq_len]
        return out
