"""Graph500 unpermuted power-law Kronecker generator (paper §IV-A, ref [22]).

Scale ``s`` and average degree ``d`` produce 2**s vertices and d * 2**s
edges. 'Unpermuted' = no vertex relabeling pass, exactly as the paper's
ingest benchmark uses. Matches the Graph500 reference kronecker generator
(A, B, C = 0.57, 0.19, 0.19).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

A, B, C = 0.57, 0.19, 0.19


def kronecker_edges(scale: int, edges_per_vertex: int = 16,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(start_vertices, end_vertices) int32 arrays, 0-based ids."""
    m = edges_per_vertex * (1 << scale)
    rng = np.random.default_rng(seed)
    ij = np.zeros((2, m), dtype=np.int64)
    ab = A + B
    c_norm = C / (1.0 - ab)
    a_norm = A / ab
    for ib in range(scale):
        ii_bit = rng.random(m) > ab
        jj_bit = rng.random(m) > (c_norm * ii_bit + a_norm * ~ii_bit)
        ij[0] += (1 << ib) * ii_bit
        ij[1] += (1 << ib) * jj_bit
    return ij[0].astype(np.int32), ij[1].astype(np.int32)


def vertex_strings(ids: np.ndarray) -> np.ndarray:
    """D4M-style string vertex keys ('v0000123') — fixed width so string
    sort order == numeric order (range queries behave)."""
    return np.asarray([f"v{int(i):08d}" for i in ids], dtype=object)


def graph500_triples(scale: int, edges_per_vertex: int = 16, seed: int = 0):
    """(row_strs, col_strs, ones) ready for putTriple."""
    u, v = kronecker_edges(scale, edges_per_vertex, seed)
    return vertex_strings(u), vertex_strings(v), np.ones(len(u), np.float32)
