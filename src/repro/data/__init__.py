from .graph500 import graph500_triples, kronecker_edges, vertex_strings
from .tokens import TokenStore, synthetic_corpus

__all__ = ["graph500_triples", "kronecker_edges", "vertex_strings",
           "TokenStore", "synthetic_corpus"]
