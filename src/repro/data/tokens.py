"""LM data pipeline on the D4M data plane (DESIGN §4).

Training corpora are ingested as (doc, position) -> token triples into the
sharded KV store; batch assembly is a row query per document. This makes the
paper's ingest/query throughput literally the training-input throughput, and
gives the trainer restartable, queryable data lineage (the same store also
holds checkpoint manifests).
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from .kvstore_backed import TokenStore  # re-export

__all__ = ["TokenStore", "synthetic_corpus"]


def synthetic_corpus(n_docs: int, doc_len: int, vocab: int,
                     seed: int = 0) -> List[np.ndarray]:
    """Zipf-distributed token documents (power-law, like the graph bench)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks
    p /= p.sum()
    return [rng.choice(vocab, size=doc_len, p=p).astype(np.int32)
            for _ in range(n_docs)]
