"""jax version compatibility shims shared across the codebase."""
import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level, check_vma spelling
    shard_map = jax.shard_map
    SHARD_MAP_KW = {"check_vma": False}
else:  # jax 0.4/0.5: experimental namespace, check_rep spelling
    from jax.experimental.shard_map import shard_map
    SHARD_MAP_KW = {"check_rep": False}

def make_mesh_auto(shape, axes, devices=None):
    """jax.make_mesh with Auto axis types on every jax version (the
    explicit-sharding AxisType API only exists from jax 0.5)."""
    kw = {} if devices is None else {"devices": devices}
    try:
        from jax.sharding import AxisType
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    except ImportError:
        pass  # older jax: Auto is the only behavior
    return jax.make_mesh(shape, axes, **kw)


__all__ = ["SHARD_MAP_KW", "make_mesh_auto", "shard_map"]
