"""Mixture-of-Experts layer: top-k router + sort-based scatter dispatch.

Dispatch avoids the GShard one-hot combine tensor ([T, E, C] is hopeless at
kimi-k2 scale): tokens are flat-sorted by expert id, positioned within their
expert via rank arithmetic, and scattered into a dense [E, C, d] buffer whose
expert dim is sharded over the EP axis (XLA inserts the all-to-all). Overflow
beyond capacity C = ceil(T*k/E * capacity_factor) is dropped (tracked by the
aux loss, standard practice).
"""
from __future__ import annotations

import dataclasses

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .spec import PSpec
from . import layers


def moe_specs(cfg: ModelConfig, L=()) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    lax_ = tuple([None] * len(L))
    dt = cfg.dtype
    specs = {
        "router": PSpec(L + (d, e), lax_ + ("embed", None), jnp.float32),
        "w_gate": PSpec(L + (e, d, f), lax_ + ("experts", "embed", None), dt),
        "w_up": PSpec(L + (e, d, f), lax_ + ("experts", "embed", None), dt),
        "w_down": PSpec(L + (e, f, d), lax_ + ("experts", None, "embed"), dt),
    }
    if cfg.n_shared_experts:
        shared = dataclasses.replace(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
        specs["shared"] = layers.mlp_specs(shared, L)
    return specs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.n_experts) + 1
    return -(-c // 8) * 8  # keep the E-buffer lane-aligned


def apply_moe(cfg: ModelConfig, p: Dict, x: jax.Array, sh
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss).

    Distributed lowering uses the shard_map EP path (local dispatch + expert
    all-to-all). Under plain jit the token sort crosses the sharded token
    dim and XLA falls back to replicate-and-sort — measured at 33k
    all-gathers / 1.7 TB temps for kimi-k2 (EXPERIMENTS §Perf, rejected
    baseline)."""
    rules = getattr(sh, "rules", None)
    mesh = getattr(sh, "mesh", None)
    if (rules is not None and mesh is not None
            and x.shape[1] % mesh.shape[rules.model] == 0 and x.shape[1] > 1):
        return _apply_moe_spmd(cfg, p, x, sh, rules, mesh)
    return _apply_moe_local(cfg, p, x, sh)


def _apply_moe_local(cfg: ModelConfig, p: Dict, x: jax.Array, sh
                     ) -> Tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)               # [t, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- dispatch: sort (token, expert) pairs by expert --------------------
    flat_e = eidx.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(t * k)
    order = jnp.argsort(flat_e)                              # stable
    se, stok, sgate = flat_e[order], flat_tok[order], flat_gate[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    cap = capacity(cfg, t)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos_in_e, e * cap)

    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xt[stok], mode="drop")
    buf = sh(buf.reshape(e, cap, d), "experts", None, None)

    # ---- expert FFN (swiglu), E sharded over the EP axis -------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = sh(jax.nn.silu(g) * u, "experts", None, None)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = sh(out, "experts", None, None).reshape(e * cap, d)

    # ---- combine ------------------------------------------------------------
    contrib = out[jnp.minimum(slot, e * cap - 1)] * sgate[:, None].astype(x.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(contrib)
    y = sh(y.reshape(b, s, d), "batch", "seq", "model_dim_act")

    if cfg.n_shared_experts:
        shared_cfg = dataclasses.replace(
            cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
        y = y + layers.apply_mlp(shared_cfg, p["shared"], x, sh)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                    # [e]
    ce = jnp.mean((jax.nn.one_hot(eidx, e, dtype=jnp.float32)
                   ).sum(1), axis=0)                                 # [e]
    aux = e * jnp.sum(me * ce)
    return y, aux


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gather_w_int8(w_local, axis_name: str, gather_axis: int):
    """FSDP weight gather with an int8 wire format (+ per-slice f32 scales).

    Halves the dominant collective term of giant-MoE training (the 3x-per-
    step expert weight gathers) at the cost of int8-quantized weights in the
    forward/recompute passes. Backward is exact: the gradient reduce-scatter
    (transpose of the gather) stays bf16.
    """
    return _gather_w_int8_impl(w_local, axis_name, gather_axis)


def _gather_w_int8_impl(w_local, axis_name, gather_axis):
    wf = w_local.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=gather_axis, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    qg = jax.lax.all_gather(q, axis_name, axis=gather_axis, tiled=True)
    sg = jax.lax.all_gather(scale.astype(jnp.float32), axis_name,
                            axis=gather_axis, tiled=True)
    n = sg.shape[gather_axis]
    shape = qg.shape
    seg = shape[gather_axis] // n
    new_shape = shape[:gather_axis] + (n, seg) + shape[gather_axis + 1:]
    qr = qg.reshape(new_shape)
    sr = jnp.expand_dims(sg, gather_axis + 1)
    return (qr.astype(jnp.float32) * sr).reshape(shape).astype(w_local.dtype)


def _gather_w_int8_fwd(w_local, axis_name, gather_axis):
    return _gather_w_int8_impl(w_local, axis_name, gather_axis), None


def _gather_w_int8_bwd(axis_name, gather_axis, _, g):
    return (jax.lax.psum_scatter(g, axis_name,
                                 scatter_dimension=gather_axis, tiled=True),)


gather_w_int8.defvjp(_gather_w_int8_fwd, _gather_w_int8_bwd)


def _apply_moe_spmd(cfg: ModelConfig, p: Dict, x: jax.Array, sh, rules, mesh
                    ) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism the way real MoE frameworks run it: tokens stay
    local to their (data × sequence) shard, dispatch is a LOCAL sort, and
    only the dense [E, C, d] buffers cross the EP axis via all_to_all.

    Layout inside shard_map (full mesh):
      x            [b/|batch|, s/|model|, d]   per device
      w_gate/up/dn [E/|model|, ...]            per device (EP weights)
      buf          [E, C_loc, d] --all_to_all--> [E/|model|, |model|·C_loc, d]
    """
    import dataclasses as _dc

    ep = rules.model
    ep_size = mesh.shape[ep]
    batch_axes = tuple(a for a in rules.batch)
    all_axes = batch_axes + (ep,)
    e = cfg.n_experts
    P_ = jax.sharding.PartitionSpec

    f_ax = rules.fsdp
    use_int8 = (rules.moe_gather == "int8" and f_ax is not None
                and cfg.d_model % mesh.shape[f_ax] == 0)

    def shard_fn(xl, router, wg, wu, wd):
        if use_int8:  # manual int8-wire FSDP gather of expert weights
            wg = gather_w_int8(wg, f_ax, 1)
            wu = gather_w_int8(wu, f_ax, 1)
            wd = gather_w_int8(wd, f_ax, 2)
        b_l, s_l, d = xl.shape
        t = b_l * s_l
        xt = xl.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        flat_e = eidx.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32),
                              cfg.experts_per_token)
        order = jnp.argsort(flat_e)                    # LOCAL sort
        se, stok = flat_e[order], flat_tok[order]
        sgate = gate_vals.reshape(-1)[order]
        starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
        pos = jnp.arange(se.shape[0], dtype=jnp.int32) \
            - starts[se].astype(jnp.int32)
        cap = capacity(cfg, t)
        keep = pos < cap
        slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, e * cap)

        buf = jnp.zeros((e * cap, d), xl.dtype).at[slot].set(
            xt[stok], mode="drop").reshape(e, cap, d)
        # EP exchange: experts -> owning rank, tokens from all ranks
        buf = jax.lax.all_to_all(buf, ep, split_axis=0, concat_axis=1,
                                 tiled=True)           # [E/ep, ep*cap, d]
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        out = jax.lax.all_to_all(out, ep, split_axis=1, concat_axis=0,
                                 tiled=True).reshape(e * cap, d)

        contrib = out[jnp.minimum(slot, e * cap - 1)] \
            * sgate[:, None].astype(xl.dtype)
        contrib = jnp.where(keep[:, None], contrib, 0)
        y = jnp.zeros((t, d), xl.dtype).at[stok].add(contrib)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(eidx, e, dtype=jnp.float32).sum(1), 0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(b_l, s_l, d), aux

    if use_int8:  # weights enter shard_map still fsdp-sharded
        w_specs = (P_(ep, f_ax, None), P_(ep, f_ax, None), P_(ep, None, f_ax))
    else:         # XLA gathers the fsdp dim (bf16) at the shard_map boundary
        w_specs = (P_(ep, None, None),) * 3
    from ..compat import SHARD_MAP_KW, shard_map
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P_(batch_axes, ep, None), P_(None, None)) + w_specs,
        out_specs=(P_(batch_axes, ep, None), P_()),
        **SHARD_MAP_KW)
    y, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = sh(y, "batch", "seq", "model_dim_act")
    if cfg.n_shared_experts:
        shared_cfg = _dc.replace(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
        y = y + layers.apply_mlp(shared_cfg, p["shared"], x, sh)
    return y, aux
