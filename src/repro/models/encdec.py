"""Encoder-decoder transformer (whisper-large-v3 backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, n_frames, d_model]. Positional
encoding is sinusoidal for both stacks (whisper uses sinusoidal enc /
learned dec; a 32k learned table would be an artifact of the assigned
decode shapes, so we use sinusoidal — noted in DESIGN.md)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig
from .spec import PSpec
from .transformer import REMAT_POLICIES


def sinusoidal_pos(positions: jax.Array, dim: int) -> jax.Array:
    pos = positions.astype(jnp.float32)[:, None]
    freqs = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32)
                    / dim * jnp.log(10000.0))
    ang = pos * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal(length: int, dim: int) -> jax.Array:
    return sinusoidal_pos(jnp.arange(length), dim)


def param_specs(cfg: ModelConfig) -> Dict:
    le = (cfg.n_enc_layers,)
    ld = (cfg.n_layers,)
    return {
        "embed": layers.embed_specs(cfg),
        "enc_blocks": {
            "ln1": layers.norm_specs(cfg, le),
            "attn": layers.attn_specs(cfg, le),
            "ln2": layers.norm_specs(cfg, le),
            "mlp": layers.mlp_specs(cfg, le),
        },
        "enc_final": layers.norm_specs(cfg),
        "dec_blocks": {
            "ln1": layers.norm_specs(cfg, ld),
            "attn": layers.attn_specs(cfg, ld),
            "lnx": layers.norm_specs(cfg, ld),
            "xattn": layers.attn_specs(cfg, ld),
            "ln2": layers.norm_specs(cfg, ld),
            "mlp": layers.mlp_specs(cfg, ld),
        },
        "final_norm": layers.norm_specs(cfg),
    }


def encode(cfg: ModelConfig, params: Dict, frames, sh, remat="dots_no_batch"):
    """frames: [B, F, D] precomputed frontend embeddings."""
    x = frames + sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(carry, blk):
        h, _ = layers.attention(cfg, blk["attn"],
                                layers.apply_norm(cfg, blk["ln1"], carry),
                                positions, sh, causal=False, use_rope=False)
        carry = carry + h
        h = layers.apply_mlp(cfg, blk["mlp"],
                             layers.apply_norm(cfg, blk["ln2"], carry), sh)
        return carry + h, None

    if remat != "none":
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_final"], x)


def _dec_block(cfg, blk, x, positions, enc_out, sh, cache=None, cache_pos=None,
               cross=None):
    h, kv = layers.attention(cfg, blk["attn"],
                             layers.apply_norm(cfg, blk["ln1"], x),
                             positions, sh, causal=True, use_rope=False,
                             cache=cache, cache_pos=cache_pos)
    x = x + h
    if cross is None:
        cross = layers.cross_kv(cfg, blk["xattn"], enc_out)
    h = layers.cross_attention(cfg, blk["xattn"],
                               layers.apply_norm(cfg, blk["lnx"], x), cross, sh)
    x = x + h
    h = layers.apply_mlp(cfg, blk["mlp"],
                         layers.apply_norm(cfg, blk["ln2"], x), sh)
    return x + h, kv, cross


def train_loss(cfg: ModelConfig, params: Dict, batch: Dict, sh,
               remat: str = "dots_no_batch") -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"], sh, remat)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed_tokens(params["embed"], tokens)
    x = x + sinusoidal(s, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        y, _, _ = _dec_block(cfg, blk, carry, positions, enc_out, sh)
        return y, None

    if remat != "none":
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    mask = jnp.concatenate([jnp.ones((b, s - 1), jnp.float32),
                            jnp.zeros((b, 1), jnp.float32)], 1)
    return layers.softmax_xent(cfg, logits, labels, mask)


def prefill(cfg: ModelConfig, params: Dict, frames, tokens, sh,
            max_len=None):
    """Encode audio + prefill the decoder; returns (logits, self_cache,
    cross_kv) with self_cache [L, B, Smax, KV, hd]."""
    enc_out = encode(cfg, params, frames, sh, remat="none")
    b, s = tokens.shape
    smax = max_len or s
    x = layers.embed_tokens(params["embed"], tokens)
    x = x + sinusoidal(s, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        ck = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cv = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        y, kv, cross = _dec_block(cfg, blk, carry, positions, enc_out, sh,
                                  cache=(ck, cv), cache_pos=0)
        return y, (kv, cross)

    x, (caches, cross) = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:], sh)
    return logits, caches, cross


def decode_step(cfg: ModelConfig, params: Dict, token, cache, cross, pos, sh):
    """token [B,1]; cache (k,v) [L,B,Smax,KV,hd]; cross (k,v) [L,B,F,KV,hd]."""
    x = layers.embed_tokens(params["embed"], token)
    positions = pos + jnp.zeros((1,), jnp.int32)
    x = x + sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)

    def body(carry, xs):
        blk, ck, cv, xk, xv = xs
        y, kv, _ = _dec_block(cfg, blk, carry, positions, None, sh,
                              cache=(ck, cv), cache_pos=pos, cross=(xk, xv))
        return y, kv

    x, new_cache = jax.lax.scan(
        body, x, (params["dec_blocks"],) + tuple(cache) + tuple(cross))
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    return logits, new_cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    axes = (None, "batch", "kv_seq", None, None)
    xshape = (cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd)
    xaxes = (None, "batch", None, None, None)
    return ((PSpec(shape, axes, cfg.dtype, "zeros"),
             PSpec(shape, axes, cfg.dtype, "zeros")),
            (PSpec(xshape, xaxes, cfg.dtype, "zeros"),
             PSpec(xshape, xaxes, cfg.dtype, "zeros")))
