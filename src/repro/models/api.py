"""Unified model API: build(cfg) returns step fns + input/cache specs for
every shape kind (train_4k / prefill_32k / decode_32k / long_500k).

Everything is expressed as PSpec trees so the same declaration drives CPU
smoke tests (real arrays), the multi-pod dry-run (ShapeDtypeStructs), and
sharding assignment (logical axes)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from . import encdec, hybrid, mamba2, transformer, vlm
from .config import ModelConfig
from .spec import PSpec


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_specs: Any
    train_loss: Callable          # (params, batch, sh, remat) -> loss
    prefill: Callable             # (params, batch, sh) -> (logits, state)
    decode: Callable              # (params, batch, sh) -> (logits, state)
    train_input_specs: Callable   # (gb, seq) -> PSpec dict
    prefill_input_specs: Callable
    decode_input_specs: Callable  # (gb, seq) -> PSpec dict (incl cache, pos)


def _tok_spec(gb: int, s: int) -> PSpec:
    return PSpec((gb, s), ("batch", None), jnp.int32, "zeros")


def build(cfg: ModelConfig) -> Model:  # noqa: C901 (dispatch table)
    f = cfg.family

    if f in ("dense", "moe"):
        def train(p, b, sh, remat="dots_no_batch"):
            return transformer.train_loss(cfg, p, b, sh, remat)

        def prefill(p, b, sh):
            return transformer.prefill(cfg, p, b["tokens"], sh)

        def decode(p, b, sh):
            return transformer.decode_step(cfg, p, b["token"], b["cache"],
                                           b["pos"], sh)

        def train_in(gb, s):
            return {"tokens": _tok_spec(gb, s)}

        def prefill_in(gb, s):
            return {"tokens": _tok_spec(gb, s)}

        def decode_in(gb, s):
            return {"token": _tok_spec(gb, 1),
                    "pos": PSpec((), (), jnp.int32, "zeros"),
                    "cache": transformer.cache_specs(cfg, gb, s)}

        return Model(cfg, transformer.param_specs(cfg), train, prefill,
                     decode, train_in, prefill_in, decode_in)

    if f == "vlm":
        n_img = cfg.n_img_tokens

        def train(p, b, sh, remat="dots_no_batch"):
            return vlm.train_loss(cfg, p, b, sh, remat)

        def prefill(p, b, sh):
            return vlm.prefill(cfg, p, b["img_embeds"], b["tokens"], sh)

        def decode(p, b, sh):
            return vlm.decode_step(cfg, p, b["token"], b["cache"], b["pos"], sh)

        def train_in(gb, s):
            return {"tokens": _tok_spec(gb, s - n_img),
                    "img_embeds": PSpec((gb, n_img, cfg.d_model),
                                        ("batch", None, None), cfg.dtype)}

        def prefill_in(gb, s):
            return train_in(gb, s)

        def decode_in(gb, s):
            return {"token": _tok_spec(gb, 1),
                    "pos": PSpec((), (), jnp.int32, "zeros"),
                    "cache": vlm.cache_specs(cfg, gb, s)}

        return Model(cfg, vlm.param_specs(cfg), train, prefill, decode,
                     train_in, prefill_in, decode_in)

    if f == "encdec":
        def train(p, b, sh, remat="dots_no_batch"):
            return encdec.train_loss(cfg, p, b, sh, remat)

        def prefill(p, b, sh):
            return encdec.prefill(cfg, p, b["frames"], b["tokens"], sh)

        def decode(p, b, sh):
            return encdec.decode_step(cfg, p, b["token"], b["cache"],
                                      b["cross"], b["pos"], sh)

        def frames_spec(gb):
            return PSpec((gb, cfg.n_frames, cfg.d_model),
                         ("batch", None, None), cfg.dtype)

        def train_in(gb, s):
            return {"tokens": _tok_spec(gb, s), "frames": frames_spec(gb)}

        def prefill_in(gb, s):
            return train_in(gb, s)

        def decode_in(gb, s):
            cache, cross = encdec.cache_specs(cfg, gb, s)
            return {"token": _tok_spec(gb, 1),
                    "pos": PSpec((), (), jnp.int32, "zeros"),
                    "cache": cache, "cross": cross}

        return Model(cfg, encdec.param_specs(cfg), train, prefill, decode,
                     train_in, prefill_in, decode_in)

    if f == "ssm":
        def train(p, b, sh, remat="dots_no_batch"):
            return mamba2.train_loss(cfg, p, b, sh, remat)

        def prefill(p, b, sh):
            return mamba2.prefill(cfg, p, b["tokens"], sh)

        def decode(p, b, sh):
            return mamba2.decode_step(cfg, p, b["token"], b["cache"], sh)

        def train_in(gb, s):
            return {"tokens": _tok_spec(gb, s)}

        def decode_in(gb, s):  # state is O(1) in s — the SSM selling point
            return {"token": _tok_spec(gb, 1),
                    "cache": mamba2.state_specs(cfg, gb)}

        return Model(cfg, mamba2.param_specs(cfg), train, prefill, decode,
                     train_in, train_in, decode_in)

    if f == "hybrid":
        def train(p, b, sh, remat="dots_no_batch"):
            return hybrid.train_loss(cfg, p, b, sh, remat)

        def prefill(p, b, sh):
            return hybrid.prefill(cfg, p, b["tokens"], sh)

        def decode(p, b, sh):
            return hybrid.decode_step(cfg, p, b["token"], b["cache"],
                                      b["pos"], sh)

        def train_in(gb, s):
            return {"tokens": _tok_spec(gb, s)}

        def decode_in(gb, s):
            return {"token": _tok_spec(gb, 1),
                    "pos": PSpec((), (), jnp.int32, "zeros"),
                    "cache": hybrid.state_specs(cfg, gb, s)}

        return Model(cfg, hybrid.param_specs(cfg), train, prefill, decode,
                     train_in, train_in, decode_in)

    raise ValueError(f"unknown family {f!r}")
