"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128

    # hybrid (Zamba2-style shared attention block)
    shared_attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_frames: int = 1500             # conv-frontend output length (stub)

    # vlm (internvl)
    n_img_tokens: int = 0

    # capability flags
    supports_long: bool = False      # sub-quadratic path for long_500k
    has_decoder: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, 2048)  # keeps vocab shardable by 16

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def n_params_analytic(self) -> int:
        """Total parameter count (for 6·N·D roofline bookkeeping)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mlp == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts \
                + self.n_shared_experts * 3 * d * self.d_ff
        if self.family == "ssm":
            attn = 0
            mlp = self._mamba_params()
        if self.family == "hybrid":
            n_shared = max(self.n_layers // max(self.shared_attn_every, 1), 1)
            shared = attn + 3 * d * self.d_ff
            return emb + self.n_layers * self._mamba_params() + shared \
                + n_shared * 2 * d  # per-invocation norms
        layers = self.n_layers if self.family != "encdec" \
            else self.n_enc_layers + self.n_layers
        if self.family == "encdec":
            attn = attn * 2  # self + cross in decoder (approx; enc has one)
        return emb + layers * (attn + mlp)

    def _mamba_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        h = self.ssm_heads
        in_proj = d * (2 * di + 2 * ns + h)
        return in_proj + (di + 2 * ns) * self.ssm_conv + di * d + 3 * h + di

    def n_params_active(self) -> int:
        """Active params per token (MoE: routed top-k + shared)."""
        if self.family != "moe":
            return self.n_params_analytic()
        d = self.d_model
        routed_inactive = self.n_layers * \
            (self.n_experts - self.experts_per_token) * 3 * d * self.d_ff
        return self.n_params_analytic() - routed_inactive
