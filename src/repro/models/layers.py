"""Shared transformer layers: norms, RoPE, GQA attention (+KV cache), MLPs.

Functional style: params are dict pytrees declared via PSpec (spec.py);
every forward takes an activation-sharding hook ``sh`` (identity on CPU).
All math in bf16 with f32 softmax/norm accumulations.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .spec import PSpec


# ------------------------------------------------------------------- norms
def norm_specs(cfg: ModelConfig, prefix_shape=()) -> Dict:
    base = {"scale": PSpec(prefix_shape + (cfg.d_model,),
                           tuple([None] * len(prefix_shape)) + (None,),
                           dtype=jnp.float32, init="ones")}
    if cfg.norm == "layernorm":
        base["bias"] = PSpec(prefix_shape + (cfg.d_model,),
                             tuple([None] * len(prefix_shape)) + (None,),
                             dtype=jnp.float32, init="zeros")
    return base


def apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6) + 0.0
    y = y * p["scale"]
    if cfg.norm == "layernorm":
        y = y + p["bias"]
    return y.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def attn_specs(cfg: ModelConfig, L=(), n_heads=None, n_kv=None) -> Dict:
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    d, hd = cfg.d_model, cfg.hd
    lax_ = tuple([None] * len(L))
    dt = cfg.dtype
    p = {
        "wq": PSpec(L + (d, h * hd), lax_ + ("embed", "heads"), dt),
        "wk": PSpec(L + (d, kv * hd), lax_ + ("embed", "kv_heads"), dt),
        "wv": PSpec(L + (d, kv * hd), lax_ + ("embed", "kv_heads"), dt),
        "wo": PSpec(L + (h * hd, d), lax_ + ("heads", "embed"), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec(L + (h * hd,), lax_ + ("heads",), jnp.float32, "zeros")
        p["bk"] = PSpec(L + (kv * hd,), lax_ + ("kv_heads",), jnp.float32, "zeros")
        p["bv"] = PSpec(L + (kv * hd,), lax_ + ("kv_heads",), jnp.float32, "zeros")
    return p


def _project_qkv(cfg, p, x, sh, n_heads, n_kv):
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = sh(q, "batch", "seq_inner", "heads")
    k = sh(k, "batch", "seq_inner", "kv_heads")
    v = sh(v, "batch", "seq_inner", "kv_heads")
    b, s = x.shape[:2]
    return (q.reshape(b, s, n_heads, hd), k.reshape(b, s, n_kv, hd),
            v.reshape(b, s, n_kv, hd))


BLOCKED_ATTN_MIN_SQ = 4096  # above this, use online-softmax blocked attention


def _blocked_sdpa_impl(q, k, v, sh=None, *, causal: bool, q_offset=None,
                       qb: int = 512, kb: int = 1024):
    """Flash-style blocked attention in pure jnp (scan over q blocks, online
    softmax over kv blocks). Peak memory is O(qb·kb) per head-group instead
    of O(Sq·Sk) — required for the 32k cells; XLA fuses the inner body.

    Causal masking is applied per block pair; blocks entirely above the
    diagonal still execute (static trip counts) — the ~2x attention-FLOP
    overhead vs. an ideal kernel is visible in the roofline and addressed in
    EXPERIMENTS §Perf.
    """
    b, sq, h, hd = q.shape
    kvh, sk = k.shape[2], k.shape[1]
    rep = h // kvh
    qb = min(qb, sq)
    kb = min(kb, sk)
    assert sq % qb == 0 and sk % kb == 0, (sq, qb, sk, kb)
    nq, nk = sq // qb, sk // kb
    scale = hd ** -0.5
    if sh is None:
        sh = lambda x, *axes: x  # noqa: E731
    qg = q.reshape(b, nq, qb, kvh, rep, hd).transpose(1, 0, 3, 4, 2, 5)
    # context parallelism: shard the q rows of each block over the TP axis.
    # GQA head counts (2/3/8/9/56...) rarely divide the model axis, so head
    # sharding degenerates to replication; the qb dim (512) always divides.
    qg = sh(qg, None, "batch", None, None, "attn_q", None)
    kg = k.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 3, 2, 4)
    vg = v.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 3, 2, 4)
    offs = 0 if q_offset is None else q_offset

    def q_block(_, xs):
        qb_dat, qi = xs                       # [b,g,r,qb,hd], scalar
        qpos = offs + qi * qb + jnp.arange(qb)

        def kv_block(carry, xs2):
            m, l, acc = carry
            kd, vd, ki = xs2                  # [b,g,kb,hd] x2, scalar
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb_dat, kd,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                kpos = ki * kb + jnp.arange(kb)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            # cast P to bf16 for the PV matmul (standard flash practice:
            # halves P traffic and feeds the MXU; accumulation stays f32)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(vd.dtype), vd,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, rep, qb), -jnp.inf, jnp.float32),
                jnp.zeros((b, kvh, rep, qb), jnp.float32),
                jnp.zeros((b, kvh, rep, qb, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (kg, vg, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, blocks = jax.lax.scan(q_block, None, (qg, jnp.arange(nq)))
    blocks = sh(blocks, None, "batch", None, None, "attn_q", None)
    # [nq, b, g, r, qb, hd] -> [b, sq, h, hd]
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def _blocked_sdpa(q, k, v, sh=None, *, causal: bool, q_offset=None,
                  qb: int = 512, kb: int = 1024):
    """Flash-attention backward = recompute scores: the whole blocked SDPA is
    its own remat island so a surrounding checkpoint_dots policy can never
    stash the O(S·kb) score blocks produced inside the scans (which would
    defeat the blocking entirely)."""
    fn = jax.checkpoint(
        lambda q_, k_, v_: _blocked_sdpa_impl(
            q_, k_, v_, sh, causal=causal, q_offset=q_offset, qb=qb, kb=kb),
        policy=jax.checkpoint_policies.nothing_saveable, prevent_cse=False)
    return fn(q, k, v)


def _sdpa(q, k, v, *, causal: bool, q_offset=None):
    """q: [B,Sq,H,hd]; k/v: [B,Sk,KV,hd]. Grouped GQA einsum (no repeat of
    the KV tensor — matters for 32k-context decode memory). f32 softmax."""
    b, sq, h, hd = q.shape
    kvh, sk = k.shape[2], k.shape[1]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    scores = scores / (hd ** 0.5)
    if causal:
        qpos = jnp.arange(sq)[:, None]
        if q_offset is not None:
            qpos = qpos + q_offset
        mask = qpos >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd)


def attention(cfg: ModelConfig, p: Dict, x: jax.Array, positions, sh,
              *, causal=True, n_heads=None, n_kv=None, use_rope=True,
              cache: Optional[Tuple] = None, cache_pos=None):
    """Self-attention. ``cache=(k,v)`` of shape [B,Smax,KV,hd] enables
    decode (x is the new token(s)); returns (out, new_cache)."""
    h = n_heads or cfg.n_heads
    kv = n_kv or cfg.n_kv_heads
    q, k, v = _project_qkv(cfg, p, x, sh, h, kv)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    blocked = causal and q.shape[1] >= BLOCKED_ATTN_MIN_SQ
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_pos, axis=1)
        ck = sh(ck, "batch", "kv_seq", None, None)
        cv = sh(cv, "batch", "kv_seq", None, None)
        if blocked:
            att = _blocked_sdpa(q, ck, cv, sh, causal=causal,
                                q_offset=cache_pos)
        else:
            att = _sdpa(q, ck, cv, causal=causal, q_offset=cache_pos)
        new_cache = (ck, cv)
    else:
        if blocked:
            att = _blocked_sdpa(q, k, v, sh, causal=causal)
        else:
            att = _sdpa(q, k, v, causal=causal)
        new_cache = None
    b, sq = x.shape[:2]
    att = sh(att.reshape(b, sq, h * cfg.hd), "batch", "seq_inner", "heads")
    out = jnp.einsum("bsh,hd->bsd", att, p["wo"])
    return sh(out, "batch", "seq", "model_dim_act"), new_cache


def cross_attention(cfg: ModelConfig, p: Dict, x, kv_cache, sh):
    """Decoder cross-attn over precomputed encoder K/V [B,Senc,KV,hd]."""
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(
        x.shape[0], x.shape[1], h, hd)
    k, v = kv_cache
    att = _sdpa(q, k, v, causal=False)
    att = att.reshape(x.shape[0], x.shape[1], h * hd)
    return jnp.einsum("bsh,hd->bsd", att, p["wo"])


def cross_kv(cfg: ModelConfig, p: Dict, enc_out: jax.Array):
    kvh, hd = cfg.n_kv_heads, cfg.hd
    b, s, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, s, kvh, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, s, kvh, hd)
    return k, v


# ---------------------------------------------------------------------- mlp
def mlp_specs(cfg: ModelConfig, L=(), d_ff=None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lax_ = tuple([None] * len(L))
    dt = cfg.dtype
    if cfg.mlp == "swiglu":
        return {
            "w_gate": PSpec(L + (d, f), lax_ + ("embed", "ff"), dt),
            "w_up": PSpec(L + (d, f), lax_ + ("embed", "ff"), dt),
            "w_down": PSpec(L + (f, d), lax_ + ("ff", "embed"), dt),
        }
    return {
        "w_in": PSpec(L + (d, f), lax_ + ("embed", "ff"), dt),
        "w_out": PSpec(L + (f, d), lax_ + ("ff", "embed"), dt),
    }


def apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array, sh) -> jax.Array:
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = sh(jax.nn.silu(g) * u, "batch", "seq_inner", "ff")
        out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"]))
        h = sh(h, "batch", "seq_inner", "ff")
        out = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return sh(out, "batch", "seq", "model_dim_act")


# ------------------------------------------------------------------- embed
def embed_specs(cfg: ModelConfig) -> Dict:
    d = {"embedding": PSpec((cfg.vocab_padded, cfg.d_model),
                            ("vocab", "embed"), cfg.dtype)}
    if not cfg.tie_embeddings:
        d["lm_head"] = PSpec((cfg.d_model, cfg.vocab_padded),
                             ("embed", "vocab"), cfg.dtype)
    return d


def embed_tokens(p: Dict, tokens: jax.Array) -> jax.Array:
    return p["embedding"][tokens]


def unembed(cfg: ModelConfig, p: Dict, x: jax.Array, sh) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    return sh(logits.astype(jnp.float32), "batch", "seq_unembed", "vocab")


def softmax_xent(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy; pad-vocab columns masked out."""
    v = logits.shape[-1]
    logits = jnp.where(jnp.arange(v)[None, None, :] < cfg.vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
