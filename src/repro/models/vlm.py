"""VLM (internvl2): vision frontend STUB + GQA LM backbone.

Per the assignment, the InternViT frontend is a stub — ``input_specs()``
provides precomputed patch embeddings [B, n_img, d_model], consumed as a
prefix ahead of the text embeddings. Loss covers text positions only.
Serving reuses the transformer decode path (the image prefix only exists at
prefill time)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import layers, transformer
from .config import ModelConfig

param_specs = transformer.param_specs
decode_step = transformer.decode_step
cache_specs = transformer.cache_specs


def train_loss(cfg: ModelConfig, params: Dict, batch: Dict, sh,
               remat: str = "dots_no_batch") -> jax.Array:
    img = batch["img_embeds"]                          # [B, n_img, D]
    tokens = batch["tokens"]                           # [B, S_text]
    n_img = img.shape[1]
    x = jnp.concatenate(
        [img.astype(cfg.dtype), layers.embed_tokens(params["embed"], tokens)],
        axis=1)
    x = sh(x, "batch", "seq", "model_dim_act")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux = transformer.apply_stack(cfg, params["blocks"], x, positions, sh,
                                     remat)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, n_img:], sh)
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], 1)
    return layers.softmax_xent(cfg, logits, labels, mask) + 0.01 * aux


def prefill(cfg: ModelConfig, params: Dict, img_embeds, tokens, sh,
            max_len=None):
    """Image prefix + prompt prefill; cache covers the combined sequence."""
    b = tokens.shape[0]
    n_img = img_embeds.shape[1]
    s = n_img + tokens.shape[1]
    smax = max_len or s
    x = jnp.concatenate(
        [img_embeds.astype(cfg.dtype),
         layers.embed_tokens(params["embed"], tokens)], axis=1)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        ck = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cv = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        y, kv, _ = transformer.apply_block(cfg, blk, carry, positions, sh,
                                           cache=(ck, cv), cache_pos=0)
        return y, kv

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:], sh)
    return logits, caches
