"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention+MLP block
applied every ``shared_attn_every`` layers with per-invocation input norms.

Simplifications vs. Zamba2 (noted in DESIGN.md): the shared block consumes
the running stream (not concat with the raw embedding) and per-invocation
LoRA specialization is replaced by per-invocation norms. The structure that
matters for systems purposes — O(1)-state Mamba layers + a small number of
full-attention applications sharing one weight set — is preserved; long-
context decode cost is dominated by the shared block's KV cache, exactly as
in Zamba2.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers, mamba2
from .config import ModelConfig
from .spec import PSpec
from .transformer import REMAT_POLICIES


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    per = cfg.shared_attn_every
    assert cfg.n_layers % per == 0
    return cfg.n_layers // per, per


def param_specs(cfg: ModelConfig) -> Dict:
    g, per = _groups(cfg)
    return {
        "embed": layers.embed_specs(cfg),
        "mamba_blocks": {
            "ln": layers.norm_specs(cfg, (g, per)),
            "mamba": mamba2.mamba_specs(cfg, (g, per)),
        },
        "shared": {
            "attn": layers.attn_specs(cfg),
            "mlp": layers.mlp_specs(cfg),
        },
        "inv_ln1": layers.norm_specs(cfg, (g,)),
        "inv_ln2": layers.norm_specs(cfg, (g,)),
        "final_norm": layers.norm_specs(cfg),
    }


def _shared_block(cfg, params, p_ln1, p_ln2, x, positions, sh,
                  cache=None, cache_pos=None):
    h, kv = layers.attention(cfg, params["shared"]["attn"],
                             layers.apply_norm(cfg, p_ln1, x), positions, sh,
                             causal=True, cache=cache, cache_pos=cache_pos)
    x = x + h
    h = layers.apply_mlp(cfg, params["shared"]["mlp"],
                         layers.apply_norm(cfg, p_ln2, x), sh)
    return x + h, kv


def train_loss(cfg: ModelConfig, params: Dict, batch: Dict, sh,
               remat: str = "dots_no_batch") -> jax.Array:
    tokens = batch["tokens"]
    x = layers.embed_tokens(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def group_body(carry, xs):
        mblk, ln1, ln2 = xs

        def inner(c, blk):
            h, _ = mamba2.apply_mamba(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], c), sh)
            return c + h, None

        y, _ = jax.lax.scan(inner, carry, mblk)
        y, _ = _shared_block(cfg, params, ln1, ln2, y, positions, sh)
        return y, None

    if remat != "none":
        group_body = jax.checkpoint(group_body, policy=REMAT_POLICIES[remat],
                                    prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x,
                        (params["mamba_blocks"], params["inv_ln1"],
                         params["inv_ln2"]))
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], 1)
    return layers.softmax_xent(cfg, logits, labels, mask)


def prefill(cfg: ModelConfig, params: Dict, tokens, sh, max_len=None):
    b, s = tokens.shape
    smax = max_len or s
    x = layers.embed_tokens(params["embed"], tokens)
    positions = jnp.arange(s, dtype=jnp.int32)

    def group_body(carry, xs):
        mblk, ln1, ln2 = xs

        def inner(c, blk):
            h, st = mamba2.apply_mamba(
                cfg, blk["mamba"], layers.apply_norm(cfg, blk["ln"], c), sh,
                return_state=True)
            return c + h, st

        y, mstates = jax.lax.scan(inner, carry, mblk)
        ck = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cv = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        y, kv = _shared_block(cfg, params, ln1, ln2, y, positions, sh,
                              cache=(ck, cv), cache_pos=0)
        return y, (mstates, kv)

    x, (mstates, kvs) = jax.lax.scan(
        group_body, x, (params["mamba_blocks"], params["inv_ln1"],
                        params["inv_ln2"]))
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:], sh)
    return logits, (mstates, kvs)


def decode_step(cfg: ModelConfig, params: Dict, token, states, pos, sh):
    """states = ((ssm [G,per,B,H,P,N], conv [G,per,B,K-1,C]),
                 (ck [G,B,Smax,KV,hd], cv [G,B,Smax,KV,hd]))."""
    (ssm, conv), (ck, cv) = states
    x = layers.embed_tokens(params["embed"], token)[:, 0, :]
    positions = pos + jnp.zeros((1,), jnp.int32)

    def group_body(carry, xs):
        mblk, ln1, ln2, ss_g, cs_g, ck_g, cv_g = xs

        def inner(c, blk_state):
            blk, ss, cs = blk_state
            xn = layers.apply_norm(cfg, blk["ln"], c[:, None, :])[:, 0, :]
            h, new_ss, new_cs = mamba2.mamba_decode(cfg, blk["mamba"], xn,
                                                    ss, cs, sh)
            return c + h, (new_ss, new_cs)

        y, new_m = jax.lax.scan(inner, carry, (mblk, ss_g, cs_g))
        y2, kv = _shared_block(cfg, params, ln1, ln2, y[:, None, :], positions,
                               sh, cache=(ck_g, cv_g), cache_pos=pos)
        return y2[:, 0, :], (new_m, kv)

    x, (new_m, new_kv) = jax.lax.scan(
        group_body, x,
        (params["mamba_blocks"], params["inv_ln1"], params["inv_ln2"],
         ssm, conv, ck, cv))
    x = layers.apply_norm(cfg, params["final_norm"], x[:, None, :])
    logits = layers.unembed(cfg, params["embed"], x, sh)
    return logits, (new_m, new_kv)


def state_specs(cfg: ModelConfig, batch: int, max_len: int):
    g, per = _groups(cfg)
    di, n = cfg.d_inner, cfg.ssm_state
    ssm = PSpec((g, per, batch, cfg.ssm_heads, cfg.ssm_headdim, n),
                (None, None, "batch", None, None, None), jnp.float32, "zeros")
    conv = PSpec((g, per, batch, cfg.ssm_conv - 1, di + 2 * n),
                 (None, None, "batch", None, "d_inner"), cfg.dtype, "zeros")
    kv = PSpec((g, batch, max_len, cfg.n_kv_heads, cfg.hd),
               (None, "batch", "kv_seq", None, None), cfg.dtype, "zeros")
    return ((ssm, conv), (kv, kv))
