from .api import Model, build
from .config import ModelConfig
from .spec import (PSpec, ShardingRules, init_params, make_sharder,
                   param_count, pspec_tree, sds_tree, sharding_tree)

__all__ = ["Model", "build", "ModelConfig", "PSpec", "ShardingRules",
           "init_params", "make_sharder", "param_count", "pspec_tree",
           "sds_tree", "sharding_tree"]
