"""Parameter specs: one declaration drives init, dry-run ShapeDtypeStructs,
and mesh shardings (logical-axis -> PartitionSpec rules)."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PSpec:
    """Shape + dtype + logical axis names for one parameter leaf."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical name per dim (None = replicated)
    dtype: Any = jnp.bfloat16
    init: str = "normal"              # normal | zeros | ones

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x):
    return isinstance(x, PSpec)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis mapping. The hillclimb loop edits THIS."""
    batch: Tuple[str, ...] = ("data",)       # data-parallel axes
    model: str = "model"                     # tensor-parallel axis
    fsdp: Optional[str] = None               # axis for ZeRO-3 param sharding
    seq: Optional[str] = None                # sequence parallelism (acts)
    kv_seq: Optional[str] = None             # decode KV-cache sequence axis
    expert: Optional[str] = "model"          # expert parallelism
    tp_enabled: bool = True                  # False: replicate weights, use
                                             # the model axis for seq/attn_q
    vocab_mode: str = "tp"                   # "tp" | "replicated"
    moe_gather: str = "bf16"                 # "bf16" | "int8": wire format of
                                             # the FSDP expert-weight gather

    def of(self, logical: Optional[str]):
        if logical is None:
            return None
        tp = self.model if self.tp_enabled else None
        vocab_m = self.model if self.vocab_mode == "tp" else None
        seq_in = None if self.tp_enabled else self.seq
        table = {
            "batch": self.batch,
            "vocab": vocab_m,
            "heads": tp,           # flattened n_heads*head_dim dim
            "kv_heads": tp,
            "ff": tp,
            "d_inner": tp,
            "experts": self.expert,
            "attn_q": self.model,   # context-parallel blocked attention
            "embed": self.fsdp,    # d_model dim of weights (ZeRO-3 slot)
            "seq": self.seq,
            # inside TP regions (projections/logits) the model axis is busy
            # with heads/ff/vocab: Megatron-SP gathers seq there. Without TP
            # the model axis is free for seq everywhere.
            "seq_inner": seq_in,
            # unembed: vocab sharding wins the model axis over seq sharding
            "seq_unembed": None if vocab_m else seq_in,
            "kv_seq": self.kv_seq,
            "model_dim_act": None,  # activations' d_model dim
        }
        return table.get(logical, None)

    def pspec(self, axes: Tuple[Optional[str], ...]) -> P:
        return P(*[self.of(a) for a in axes])

    def pspec_for_shape(self, shape, axes, mesh) -> P:
        """Divisibility- and uniqueness-aware spec: drop mesh axes that do
        not divide the dim (batch=1 long-context cells) or that an earlier
        dim already claimed (e.g. vocab=model + 2D fsdp=(data, model))."""
        out = []
        used = set()
        for dim, logical in zip(shape, axes):
            m = self.of(logical)
            if m is None:
                out.append(None)
                continue
            names = [n for n in ((m,) if isinstance(m, str) else tuple(m))
                     if n not in used]
            prod = 1
            for nm in names:
                prod *= mesh.shape[nm]
            if not names or dim % prod != 0:
                out.append(None)
                continue
            used.update(names)
            out.append(names[0] if len(names) == 1 else tuple(names))
        return P(*out)


def init_params(specs, key, scale: float = 0.02):
    """Materialize real parameters from a spec tree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = min(scale, (1.0 / max(fan_in, 1)) ** 0.5)
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std
                        ).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def sds_tree(specs):
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        specs, is_leaf=_is_spec)


def sharding_tree(specs, rules: ShardingRules, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, rules.pspec_for_shape(s.shape, s.axes,
                                                            mesh)),
        specs, is_leaf=_is_spec)


def pspec_tree(specs, rules: ShardingRules):
    return jax.tree.map(lambda s: rules.pspec(s.axes), specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def make_sharder(rules: Optional[ShardingRules], mesh=None):
    """Activation-sharding hook threaded through the model code.

    sh(x, 'batch', None, 'heads') applies with_sharding_constraint when rules
    are present (distributed lowering) and is identity on CPU tests.
    """
    if rules is None:
        return lambda x, *axes: x

    def sh(x, *axes):
        if mesh is not None:
            spec = rules.pspec_for_shape(x.shape, axes, mesh)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return jax.lax.with_sharding_constraint(x, rules.pspec(axes))

    sh.rules = rules   # shard_map-based layers (MoE EP) read these
    sh.mesh = mesh
    return sh
