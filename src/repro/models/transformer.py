"""Decoder-only transformer LM (dense GQA + MoE variants).

Layers are parameter-stacked and driven by ``lax.scan`` (fast compiles at
60+ layers, remat-friendly). Exposes the three step kinds the shape grid
needs: train loss, prefill (builds KV cache), and single-token decode.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, moe
from .config import ModelConfig
from .spec import PSpec

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "nothing": jax.checkpoint_policies.nothing_saveable,
}


def block_specs(cfg: ModelConfig, L: Tuple[int, ...]) -> Dict:
    sp = {
        "ln1": layers.norm_specs(cfg, L),
        "ln2": layers.norm_specs(cfg, L),
        "attn": layers.attn_specs(cfg, L),
    }
    if cfg.family == "moe":
        sp["moe"] = moe.moe_specs(cfg, L)
    else:
        sp["mlp"] = layers.mlp_specs(cfg, L)
    return sp


def param_specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": layers.embed_specs(cfg),
        "blocks": block_specs(cfg, (cfg.n_layers,)),
        "final_norm": layers.norm_specs(cfg),
    }


def apply_block(cfg: ModelConfig, p: Dict, x, positions, sh, *,
                cache=None, cache_pos=None):
    h, new_kv = layers.attention(
        cfg, p["attn"], layers.apply_norm(cfg, p["ln1"], x), positions, sh,
        causal=True, cache=cache, cache_pos=cache_pos)
    x = x + h
    hn = layers.apply_norm(cfg, p["ln2"], x)
    if cfg.family == "moe":
        h, aux = moe.apply_moe(cfg, p["moe"], hn, sh)
    else:
        h, aux = layers.apply_mlp(cfg, p["mlp"], hn, sh), jnp.zeros((), jnp.float32)
    return x + h, new_kv, aux


def apply_stack(cfg: ModelConfig, blocks: Dict, x, positions, sh,
                remat: str = "dots_no_batch"):
    """Train/prefill-without-cache path: scan blocks, return (x, aux_sum)."""

    def body(carry, blk):
        y, _, aux = apply_block(cfg, blk, carry, positions, sh)
        return y, aux

    policy = REMAT_POLICIES[remat]
    if remat != "none":
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, blocks)
    return x, jnp.sum(auxs)


# ------------------------------------------------------------------- train
def train_loss(cfg: ModelConfig, params: Dict, batch: Dict, sh,
               remat: str = "dots_no_batch") -> jax.Array:
    tokens = batch["tokens"]                     # [B, S]
    x = layers.embed_tokens(params["embed"], tokens)
    x = sh(x, "batch", "seq", "model_dim_act")
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x, aux = apply_stack(cfg, params["blocks"], x, positions, sh, remat)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
    loss = layers.softmax_xent(cfg, logits, labels, mask)
    return loss + 0.01 * aux


# ----------------------------------------------------------------- serving
def prefill(cfg: ModelConfig, params: Dict, tokens, sh, max_len: Optional[int] = None):
    """Forward pass that also emits the stacked KV cache [L, B, Smax, KV, hd].

    ``max_len`` pads the cache beyond the prompt for subsequent decode.
    """
    b, s = tokens.shape
    smax = max_len or s
    x = layers.embed_tokens(params["embed"], tokens)
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(carry, blk):
        ck = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        cv = jnp.zeros((b, smax, cfg.n_kv_heads, cfg.hd), cfg.dtype)
        y, kv, _ = apply_block(cfg, blk, carry, positions, sh,
                               cache=(ck, cv), cache_pos=0)
        return y, kv

    x, caches = jax.lax.scan(body, x, params["blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:], sh)
    return logits, caches


def decode_step(cfg: ModelConfig, params: Dict, token, cache, pos, sh):
    """One decode step. token: [B, 1]; cache: (k, v) stacked [L, B, S, KV, hd];
    pos: scalar int32 position of the new token."""
    x = layers.embed_tokens(params["embed"], token)
    positions = pos + jnp.zeros((1,), jnp.int32)

    def body(carry, xs):
        blk, ck, cv = xs
        y, kv, _ = apply_block(cfg, blk, carry, positions, sh,
                               cache=(ck, cv), cache_pos=pos)
        return y, kv

    x, new_cache = jax.lax.scan(body, x, (params["blocks"],) + tuple(cache))
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    return logits, new_cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """PSpec tree for the decode KV cache (dry-run + serving alloc)."""
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    axes = (None, "batch", "kv_seq", None, None)
    return (PSpec(shape, axes, cfg.dtype, "zeros"),
            PSpec(shape, axes, cfg.dtype, "zeros"))
