"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Chunked SSD: intra-chunk quadratic (attention-like, MXU-friendly) term +
inter-chunk state recurrence via ``lax.scan`` over chunks. Single-token
state update for decode (the whole point of the arch at long_500k: decode
cost is O(1) in context length).

Discretization: h_t = exp(dt_t · A) h_{t-1} + dt_t · B_t x_t ;
y_t = C_t h_t + D x_t, with per-head scalar A < 0, G=1 B/C groups.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig
from .spec import PSpec
from .transformer import REMAT_POLICIES


def mamba_specs(cfg: ModelConfig, L=()) -> Dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv
    conv_dim = di + 2 * n
    lax_ = tuple([None] * len(L))
    dt = cfg.dtype
    return {
        "in_proj": PSpec(L + (d, 2 * di + 2 * n + h),
                         lax_ + ("embed", "d_inner"), dt),
        "conv_w": PSpec(L + (conv_dim, k), lax_ + ("d_inner", None), dt),
        "conv_b": PSpec(L + (conv_dim,), lax_ + ("d_inner",), jnp.float32,
                        "zeros"),
        "A_log": PSpec(L + (h,), lax_ + (None,), jnp.float32, "ones"),
        "D": PSpec(L + (h,), lax_ + (None,), jnp.float32, "ones"),
        "dt_bias": PSpec(L + (h,), lax_ + (None,), jnp.float32, "zeros"),
        "norm": PSpec(L + (di,), lax_ + ("d_inner",), jnp.float32, "ones"),
        "out_proj": PSpec(L + (di, d), lax_ + ("d_inner", "embed"), dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [B,S,C]; w: [C,K]; returns silu(conv)."""
    k = w.shape[-1]
    s = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, j:j + s, :] * w[:, j].astype(x.dtype) for j in range(k))
    return jax.nn.silu(out + b.astype(x.dtype))


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _ssd_chunked(cfg: ModelConfig, x, dt, a_log, b_, c_,
                 init_state: Optional[jax.Array] = None):
    """x: [B,S,H,P] (already silu'd conv output); dt: [B,S,H] (softplus'd);
    b_, c_: [B,S,N] (G=1). Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s_orig, h, p = x.shape
    n = b_.shape[-1]
    q = min(cfg.ssm_chunk, s_orig)
    pad = (-s_orig) % q
    if pad:  # ragged tail: dt=0 padding is exact (decay=1, zero contribution)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q
    fa = (-jnp.exp(a_log.astype(jnp.float32)))                   # (H,) < 0
    a = dt * fa                                                  # [B,S,H]
    xdt = x * dt[..., None].astype(x.dtype)                      # dt-weighted

    # chunked views
    ac = a.reshape(bsz, nc, q, h)
    acs = jnp.cumsum(ac, axis=2)                                 # [B,nc,Q,H]
    xc = xdt.reshape(bsz, nc, q, h, p)
    bc = b_.reshape(bsz, nc, q, n)
    cc = c_.reshape(bsz, nc, q, n)

    # intra-chunk (quadratic, MXU)
    seg = acs[:, :, :, None, :] - acs[:, :, None, :, :]          # [B,nc,Q,K,H]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    l_mat = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc.astype(jnp.float32),
                    bc.astype(jnp.float32))
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp",
                        cb, l_mat, xc.astype(jnp.float32))

    # per-chunk end states
    decay_out = jnp.exp(acs[:, :, -1:, :] - acs)                 # [B,nc,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc.astype(jnp.float32),
                        decay_out, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(acs[:, :, -1, :])                      # [B,nc,H]

    # inter-chunk recurrence
    h0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(carry, xs):
        st, dec = xs                                             # [B,H,P,N],[B,H]
        new = carry * dec[:, :, None, None] + st
        return new, carry                                        # emit entering

    final_state, prev_states = jax.lax.scan(
        scan_fn, h0, (states.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # [B,nc,H,P,N]

    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc.astype(jnp.float32),
                       prev_states, jnp.exp(acs))
    y = (y_diag + y_off).reshape(bsz, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def apply_mamba(cfg: ModelConfig, p: Dict, x: jax.Array, sh,
                init_state=None, conv_init=None,
                return_state: bool = False):
    """Full mixer: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    bsz, s, _ = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_raw, dtr = _split_proj(cfg, zxbcdt)
    z = sh(z, "batch", "seq", "d_inner")
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xbc = sh(xbc, "batch", "seq", "d_inner")
    xs = xbc[..., :di].reshape(bsz, s, h, pdim)
    b_ = xbc[..., di:di + n]
    c_ = xbc[..., di + n:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    y, final_state = _ssd_chunked(cfg, xs, dt, p["A_log"], b_, c_, init_state)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, s, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gf = g.astype(jnp.float32)
    g = (gf * jax.lax.rsqrt(jnp.mean(gf * gf, -1, keepdims=True) + 1e-6)
         * p["norm"]).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", g, p["out_proj"])
    out = sh(out, "batch", "seq", "model_dim_act")
    if return_state:
        k = cfg.ssm_conv
        conv_state = jnp.pad(  # last K-1 pre-conv inputs
            xbc_raw[:, max(s - (k - 1), 0):, :],
            ((0, 0), (max(k - 1 - s, 0), 0), (0, 0)))
        return out, (final_state, conv_state)
    return out, None


def mamba_decode(cfg: ModelConfig, p: Dict, xt: jax.Array,
                 ssm_state: jax.Array, conv_state: jax.Array, sh):
    """One-token step. xt: [B,D]; ssm_state: [B,H,P,N];
    conv_state: [B,K-1,conv_dim] (pre-activation conv inputs)."""
    bsz = xt.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    zxbcdt = jnp.einsum("bd,de->be", xt, p["in_proj"])
    z = zxbcdt[:, :di]
    xbc_new = zxbcdt[:, di:2 * di + 2 * n]
    dtr = zxbcdt[:, 2 * di + 2 * n:]
    xfull = jnp.concatenate([conv_state, xbc_new[:, None, :]], axis=1)
    conv = sum(xfull[:, j, :] * p["conv_w"][:, j].astype(xt.dtype)
               for j in range(cfg.ssm_conv))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(xt.dtype))
    xs = xbc[:, :di].reshape(bsz, h, pdim).astype(jnp.float32)
    b_ = xbc[:, di:di + n].astype(jnp.float32)
    c_ = xbc[:, di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])   # [B,H]
    fa = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * fa)                                       # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xs, b_)
    new_state = ssm_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c_, new_state) \
        + p["D"][None, :, None] * xs
    y = y.reshape(bsz, di)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    g = (g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + 1e-6)
         * p["norm"]).astype(xt.dtype)
    out = jnp.einsum("bi,id->bd", g, p["out_proj"])
    return out, new_state.astype(ssm_state.dtype), xfull[:, 1:, :]


# ---------------------------------------------------------- full LM (ssm)
def param_specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": layers.embed_specs(cfg),
        "blocks": {"ln": layers.norm_specs(cfg, (cfg.n_layers,)),
                   "mamba": mamba_specs(cfg, (cfg.n_layers,))},
        "final_norm": layers.norm_specs(cfg),
    }


def train_loss(cfg: ModelConfig, params: Dict, batch: Dict, sh,
               remat: str = "dots_no_batch") -> jax.Array:
    tokens = batch["tokens"]
    x = layers.embed_tokens(params["embed"], tokens)
    x = sh(x, "batch", "seq", "model_dim_act")

    def body(carry, blk):
        h, _ = apply_mamba(cfg, blk["mamba"],
                           layers.apply_norm(cfg, blk["ln"], carry), sh)
        return carry + h, None

    if remat != "none":
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat],
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, sh)
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], 1)
    return layers.softmax_xent(cfg, logits, labels, mask)


def prefill(cfg: ModelConfig, params: Dict, tokens, sh):
    """Returns (last-token logits, (ssm_states, conv_states)) stacked [L,...]."""
    x = layers.embed_tokens(params["embed"], tokens)

    def body(carry, blk):
        h, st = apply_mamba(cfg, blk["mamba"],
                            layers.apply_norm(cfg, blk["ln"], carry), sh,
                            return_state=True)
        return carry + h, st

    x, states = jax.lax.scan(body, x, params["blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:], sh)
    return logits, states


def decode_step(cfg: ModelConfig, params: Dict, token, states, sh):
    """token: [B,1]; states: (ssm [L,B,H,P,N], conv [L,B,K-1,conv_dim])."""
    x = layers.embed_tokens(params["embed"], token)[:, 0, :]

    def body(carry, xs):
        blk, ss, cs = xs
        xn = layers.apply_norm(cfg, blk["ln"], carry[:, None, :])[:, 0, :]
        h, new_ss, new_cs = mamba_decode(cfg, blk["mamba"], xn, ss, cs, sh)
        return carry + h, (new_ss, new_cs)

    x, new_states = jax.lax.scan(body, x, (params["blocks"],) + tuple(states))
    x = layers.apply_norm(cfg, params["final_norm"], x[:, None, :])
    logits = layers.unembed(cfg, params["embed"], x, sh)
    return logits, new_states


def state_specs(cfg: ModelConfig, batch: int):
    di, n = cfg.d_inner, cfg.ssm_state
    return (
        PSpec((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim, n),
              (None, "batch", None, None, None), jnp.float32, "zeros"),
        PSpec((cfg.n_layers, batch, cfg.ssm_conv - 1, di + 2 * n),
              (None, "batch", None, "d_inner"), cfg.dtype, "zeros"),
    )
