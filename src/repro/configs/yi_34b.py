"""yi-34b [dense GQA, llama arch] — arXiv:2403.04652."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_dim=128, rope_theta=5e6, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
    vocab=512, head_dim=8)
