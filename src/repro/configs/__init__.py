from .registry import ARCH_IDS, SHAPES, all_cells, get_config, get_reduced, shapes_for

__all__ = ["ARCH_IDS", "SHAPES", "all_cells", "get_config", "get_reduced",
           "shapes_for"]
