"""whisper-large-v3 [audio enc-dec] — arXiv:2212.04356. Conv frontend is a
stub: input_specs provide precomputed frame embeddings [B, 1500, 1280]."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, mlp="gelu", norm="layernorm",
    n_frames=1500, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, n_frames=16)
