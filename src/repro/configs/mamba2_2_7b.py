"""mamba2-2.7b [attention-free SSD] — arXiv:2405.21060."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280, ssm_state=128, ssm_headdim=64, ssm_expand=2,
    supports_long=True, mlp="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16)
