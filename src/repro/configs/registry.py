"""Architecture registry: --arch <id> resolution + shape grid definitions."""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..models.config import ModelConfig
from . import (command_r_plus_104b, internvl2_26b, kimi_k2, mamba2_2_7b,
               olmoe_1b_7b, qwen2_5_3b, smollm_135m, whisper_large_v3,
               yi_34b, zamba2_2_7b)

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "qwen2.5-3b": qwen2_5_3b,
    "yi-34b": yi_34b,
    "smollm-135m": smollm_135m,
    "command-r-plus-104b": command_r_plus_104b,
    "zamba2-2.7b": zamba2_2_7b,
    "internvl2-26b": internvl2_26b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "kimi-k2-1t-a32b": kimi_k2,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCH_IDS: List[str] = list(_MODULES)

# shape id -> (seq_len, global_batch, step kind)
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].REDUCED


def shapes_for(arch: str) -> List[str]:
    """long_500k only runs for sub-quadratic archs (DESIGN §7)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long:
        out.append("long_500k")
    return out


def all_cells():
    """All 40 (arch, shape) cells; skipped ones flagged with a reason."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            skip = None
            if s == "long_500k" and not cfg.supports_long:
                skip = "full attention is O(S^2) at 524k; arch defines no sub-quadratic path"
            cells.append((a, s, skip))
    return cells
