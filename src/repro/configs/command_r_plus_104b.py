"""command-r-plus-104b [dense GQA, no-bias] — hf:CohereForAI/c4ai-command-r-plus."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab=256000, head_dim=128, tie_embeddings=True, rope_theta=75e6,
    supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=176,
    vocab=512, head_dim=8)
