"""smollm-135m [dense GQA, small llama arch] — hf:HuggingFaceTB/SmolLM-135M."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49152, tie_embeddings=True, rope_theta=1e4, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=48, n_heads=3, n_kv_heads=3, d_ff=128,
    vocab=512)
