"""qwen2.5-3b [dense GQA, QKV bias] — hf:Qwen/Qwen2.5-3B."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab=151936, qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab=512)
