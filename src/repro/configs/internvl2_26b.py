"""internvl2-26b [vlm: InternViT stub + InternLM2 backbone] — arXiv:2404.16821."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, head_dim=128, n_img_tokens=256, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, n_img_tokens=8)
