"""zamba2-2.7b [hybrid: Mamba2 backbone + shared attention] — arXiv:2411.15242."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    shared_attn_every=6, supports_long=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, ssm_state=16, ssm_headdim=16, shared_attn_every=2,
    ssm_chunk=16)
