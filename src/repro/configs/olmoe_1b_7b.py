"""olmoe-1b-7b [MoE 64e top-8] — arXiv:2409.02060."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304, n_experts=64, experts_per_token=8, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
    vocab=512, n_experts=8, experts_per_token=2)
