"""kimi-k2-1t-a32b [trillion-param MoE 384e top-8 + 1 shared expert] —
arXiv:2501 (paper-table config)."""
import dataclasses
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=112, n_experts=384, experts_per_token=8,
    n_shared_experts=1, supports_long=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=512, head_dim=16, n_experts=8, experts_per_token=2,
    n_shared_experts=1)
