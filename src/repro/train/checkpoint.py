"""Checkpoint/restart (fault tolerance, DESIGN §9).

Layout on disk:
  <dir>/step_000123/
     manifest.json        tree structure, shapes, dtypes, step, mesh note
     leaf_00000.npy ...   one file per pytree leaf
  <dir>/LATEST            atomic pointer (written via rename)

Arrays are saved as *global* host arrays, so a restore may re-shard onto any
mesh whose axes divide the shapes — that is the elastic-restart path
(train/elastic.py): shrink or grow the DP width at a checkpoint boundary.
Save is atomic (tmp dir + rename); keep_last_k prunes old steps. A restart
after a simulated node failure is covered by tests/test_fault.py.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, leaves


def save(ckpt_dir: str, step: int, tree, *, keep_last_k: int = 3,
         extra: Optional[dict] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    treedef, leaves = _leaf_paths(tree)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) == "bfloat16":  # np.save cannot round-trip bf16
            arr = arr.view(np.uint16)
        elif arr.dtype.kind == "V":
            raise TypeError(f"unsupported leaf dtype {arr.dtype}")
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "dtypes": dtypes,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _prune(ckpt_dir, keep_last_k)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``. ``shardings`` (optional
    pytree of NamedSharding) re-shards onto the *current* mesh — elastic."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    treedef, leaves_like = _leaf_paths(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: ckpt {manifest['n_leaves']} vs {len(leaves_like)}"
    import ml_dtypes
    leaves = []
    for i in range(len(leaves_like)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if manifest.get("dtypes", [None] * (i + 1))[i] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    else:
        import jax.numpy as jnp
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest
