"""AdamW with optional int8-quantized moments (distributed-optimization
trick: at kimi-k2 scale, fp32 m/v do not fit a v5e pod — int8 + per-row f32
scales cut optimizer HBM ~4x and checkpoint traffic likewise).

Quantized moments keep the parameter's exact shape and logical axes, so the
mesh sharding of the optimizer state follows the parameter sharding (ZeRO
slotting works unchanged). 1-D leaves (norm scales, biases) stay fp32 —
they are O(d) and quantization there buys nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False    # int8 m/v with per-row f32 scales


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to 10% of peak."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * warm * cos


def _quantizable(shape) -> bool:
    return len(shape) >= 2


def _q8_encode(x: jax.Array):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def _q8_decode(m) -> jax.Array:
    return m["q"].astype(jnp.float32) * m["s"]


def adamw_init(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.quantized_state and _quantizable(p.shape):
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.full(p.shape[:-1] + (1,), 1e-12, jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state). Gradients may be bf16; math in f32."""
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = lr_at(cfg, count)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        quant = cfg.quantized_state and _quantizable(p.shape)
        g = g.astype(jnp.float32) * clip
        mf = _q8_decode(m) if quant else m
        vf = _q8_decode(v) if quant else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mh = mf / (1 - cfg.b1 ** cf)
        vh = vf / (1 - cfg.b2 ** cf)
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # none on norms/bias
        new_p = (p.astype(jnp.float32)
                 - lr * (step_ + decay * p.astype(jnp.float32))).astype(p.dtype)
        if quant:
            return new_p, _q8_encode(mf), _q8_encode(vf)
        return new_p, mf, vf

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """PSpec tree for the optimizer state (drives dry-run shardings).
    Moments inherit the parameter's logical axes -> identical sharding."""
    from ..models.spec import PSpec

    def mom(s: PSpec):
        if cfg.quantized_state and _quantizable(s.shape):
            return {"q": PSpec(s.shape, s.axes, jnp.int8, "zeros"),
                    "s": PSpec(s.shape[:-1] + (1,), s.axes[:-1] + (None,),
                               jnp.float32, "zeros")}
        return PSpec(s.shape, s.axes, jnp.float32, "zeros")

    is_spec = lambda x: isinstance(x, PSpec)  # noqa: E731
    return {
        "m": jax.tree.map(mom, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(mom, param_specs, is_leaf=is_spec),
        "count": PSpec((), (), jnp.int32, "zeros"),
    }
