"""Gradient compression for cross-pod reduces (DESIGN §9).

Two schemes, both with error feedback so compression error accumulates into
the next step instead of being lost:

  * top-k sparsification — keep the k largest-|g| entries per tensor.
  * int8 quantization   — per-block scale (the wire format for the slow
    pod-interconnect hop; 4x traffic cut on the 2·S·(n-1)/n term).

On real multi-pod deployments the compressed payload is what crosses the
pod axis (a shard_map psum over 'pod' of the int8 tensors + scales);
correctness (roundtrip + convergence under error feedback) is covered by
tests/test_fault.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    scheme: str = "int8"          # "int8" | "topk" | "none"
    topk_frac: float = 0.01
    block: int = 256


# ----------------------------------------------------------------- top-k
def topk_compress(g: jax.Array, frac: float):
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    return (idx.astype(jnp.int32), sel), g.shape, flat.shape[0]


def topk_decompress(payload, shape, n: int) -> jax.Array:
    idx, vals = payload
    return jnp.zeros((n,), jnp.float32).at[idx].set(vals).reshape(shape)


# ------------------------------------------------------------------ int8
def int8_compress(g: jax.Array, block: int = 256):
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    b = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return (q, scale.astype(jnp.float32)), g.shape, n


def int8_decompress(payload, shape, n: int) -> jax.Array:
    q, scale = payload
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


# -------------------------------------------------------- error feedback
def compress_with_feedback(grads, residual, cfg: CompressConfig):
    """(compressed-then-decompressed grads, new residual).

    The returned grads are what the wire delivers; residual carries the
    quantization/sparsification error into the next step (EF-SGD)."""
    if cfg.scheme == "none":
        return grads, residual

    def one(g, r):
        c = g.astype(jnp.float32) + r
        if cfg.scheme == "topk":
            payload, shape, n = topk_compress(c, cfg.topk_frac)
            d = topk_decompress(payload, shape, n)
        else:
            payload, shape, n = int8_compress(c, cfg.block)
            d = int8_decompress(payload, shape, n)
        return d.astype(g.dtype), c - d

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def zero_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def wire_bytes(grads, cfg: CompressConfig) -> Tuple[int, int]:
    """(uncompressed fp32 bytes, compressed wire bytes) — for EXPERIMENTS."""
    import numpy as np
    raw = sum(int(np.prod(g.shape)) * 4 for g in jax.tree.leaves(grads))
    if cfg.scheme == "int8":
        comp = sum(int(np.prod(g.shape)) * (1 + 4 / cfg.block)
                   for g in jax.tree.leaves(grads))
    elif cfg.scheme == "topk":
        comp = sum(int(int(np.prod(g.shape)) * cfg.topk_frac) * 8
                   for g in jax.tree.leaves(grads))
    else:
        comp = raw
    return raw, int(comp)
