from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_at, opt_state_specs
from .train_step import make_train_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at",
           "opt_state_specs", "make_train_step"]
