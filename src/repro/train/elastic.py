"""Elastic scaling + straggler/failure handling (DESIGN §9).

Training is synchronous; the failure model is Accumulo-style at the data
plane (re-route a dead ingestor's key range) and checkpoint-elastic at the
training plane (restart on a smaller/larger DP width from the latest
checkpoint — arrays are saved as global host arrays, so any mesh whose
axes divide the shapes can restore them).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from ..models.spec import ShardingRules, sharding_tree
from . import checkpoint


def elastic_restore(ckpt_dir: str, param_specs, mesh,
                    rules: ShardingRules, step: Optional[int] = None):
    """Restore a checkpoint onto an arbitrary (possibly resized) mesh."""
    import jax.numpy as jnp
    like = jax.tree.map(
        lambda s: np.zeros((), np.float32),  # structure only
        param_specs, is_leaf=lambda x: hasattr(x, "axes"))
    shardings = sharding_tree(param_specs, rules, mesh)
    return checkpoint.restore(ckpt_dir, like, step=step, shardings=shardings)


def reassign_dead_ingestor(split_points: np.ndarray, dead: int) -> np.ndarray:
    """Accumulo tablet reassignment: merge the dead shard's key range into
    its neighbour by dropping its split point. split_points has S-1 entries
    for S shards; returns S-2 entries for S-1 shards."""
    s = len(split_points) + 1
    assert 0 <= dead < s
    drop = min(dead, len(split_points) - 1)
    return np.delete(split_points, drop)


class WorkQueue:
    """Straggler mitigation for ingest: batches are pulled, not pushed.

    A slow ingestor simply claims fewer batches; a dead one (never acks)
    has its in-flight batch re-queued after ``timeout_batches`` pulls by
    others. Used by benchmarks/ingest_bench.py --steal."""

    def __init__(self, batches, timeout_batches: int = 8):
        self.pending = list(range(len(batches)))
        self.batches = batches
        self.inflight: dict = {}
        self.done: set = set()
        self.timeout = timeout_batches
        self.clock = 0

    def claim(self, worker: int):
        self.clock += 1
        # requeue timed-out in-flight work (dead worker)
        for bid, (w, t) in list(self.inflight.items()):
            if self.clock - t > self.timeout:
                del self.inflight[bid]
                self.pending.append(bid)
        if not self.pending:
            return None, None
        bid = self.pending.pop(0)
        self.inflight[bid] = (worker, self.clock)
        return bid, self.batches[bid]

    def ack(self, bid: int) -> None:
        self.inflight.pop(bid, None)
        self.done.add(bid)

    def complete(self) -> bool:
        return len(self.done) == len(self.batches)
