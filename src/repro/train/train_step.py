"""Train-step factory: loss -> grads (optionally microbatched) -> AdamW."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..models.spec import ShardingRules, make_sharder
from .optimizer import AdamWConfig, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    rules: Optional[ShardingRules] = None, mesh=None,
                    remat: str = "dots_no_batch", microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, loss).

    ``microbatches > 1`` scans over batch slices accumulating f32 grads
    (gradient accumulation — the standard way to overlap the per-microbatch
    reduce with compute and to fit large global batches)."""
    sh = make_sharder(rules, mesh)

    def loss_fn(params, batch):
        return model.train_loss(params, batch, sh, remat)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / microbatches,
                    acc, g)
                return acc, l

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            grads, losses = jax.lax.scan(body, acc0, mb)
            loss = jnp.mean(losses)
        new_params, new_opt = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, loss

    return step
