"""String interning: the host-side boundary between D4M string keys and
device-side int32 ids.

Accumulo stores byte-string keys; TPUs do not handle variable-length data.
All strings are dictionary-encoded here, once, at the host boundary — the
device-side store (``repro.db.kvstore``) only ever sees dense int32 ids.
This is the TPU-native analogue of the JVM/JavaCall string-marshalling layer
whose overhead the paper measures.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, List

import numpy as np


class StringDict:
    """Bidirectional string <-> int32 id mapping (ids are dense, 0-based)."""

    def __init__(self, strings: Iterable[str] = ()):  # noqa: D107
        self._to_id: dict = {}
        self._to_str: List[str] = []
        if strings:
            self.encode(np.asarray(list(strings), dtype=object))

    def __len__(self) -> int:
        return len(self._to_str)

    def encode(self, strs: np.ndarray) -> np.ndarray:
        """Intern every string; returns int32 ids (allocates new ids).

        Vectorized via np.unique: the Python-level intern loop touches only
        the *unique* strings of the batch (power-law batches repeat hub
        keys constantly). This is the paper's own observation — string-array
        handling dominates connector overhead — applied at the one host
        boundary where strings still exist (DESIGN §2).
        """
        if len(strs) == 0:
            return np.zeros(0, dtype=np.int32)
        uniq, inv = np.unique(np.asarray(strs, dtype=object), return_inverse=True)
        to_id = self._to_id
        to_str = self._to_str
        uids = np.empty(len(uniq), dtype=np.int32)
        for i, s in enumerate(uniq):
            j = to_id.get(s)
            if j is None:
                j = len(to_str)
                to_id[s] = j
                to_str.append(s)
            uids[i] = j
        return uids[inv]

    def lookup(self, strs: np.ndarray) -> np.ndarray:
        """Ids for already-interned strings; -1 where unknown (no alloc)."""
        to_id = self._to_id
        return np.fromiter(
            (to_id.get(s, -1) for s in strs), dtype=np.int32, count=len(strs)
        )

    def decode(self, ids: np.ndarray) -> np.ndarray:
        arr = np.asarray(self._to_str, dtype=object)
        return arr[np.asarray(ids)]

    def get(self, s: str) -> int:
        return self._to_id.get(s, -1)

    @classmethod
    def from_strings(cls, strings) -> "StringDict":
        """Rebuild with ids assigned by POSITION (id i = strings[i]).

        The ``__init__`` path interns via ``encode`` — which dedups through
        ``np.unique`` and therefore assigns ids in *sorted* order. Recovery
        must preserve the original allocation order, so it uses this.
        """
        d = cls()
        d._to_str = list(strings)
        d._to_id = {s: i for i, s in enumerate(d._to_str)}
        return d

    # -- persistence (checkpoint manifest / restart path) -------------------
    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._to_str, f)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "StringDict":
        with open(path) as f:
            return cls.from_strings(json.load(f))
