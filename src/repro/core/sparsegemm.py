"""Vectorized host-side sparse kernels backing associative-array algebra.

COO triples (r, c, v) with int64 indices. All routines are pure numpy and
fully vectorized (no Python loops over nnz) — these are the host analogues;
the device hot paths live in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

Coo = Tuple[np.ndarray, np.ndarray, np.ndarray]


def coalesce(r: np.ndarray, c: np.ndarray, v: np.ndarray, op: str = "sum") -> Coo:
    """Sort row-major and combine duplicate (r, c) entries with ``op``."""
    if len(r) == 0:
        return r.astype(np.int64), c.astype(np.int64), v
    order = np.lexsort((c, r))
    r, c, v = r[order], c[order], v[order]
    new = np.empty(len(r), dtype=bool)
    new[0] = True
    new[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    starts = np.flatnonzero(new)
    if len(starts) == len(r):
        return r, c, v
    if op == "sum":
        vv = np.add.reduceat(v, starts)
    elif op == "min":
        vv = np.minimum.reduceat(v, starts)
    elif op == "max":
        vv = np.maximum.reduceat(v, starts)
    elif op == "first":
        vv = v[starts]
    elif op == "last":
        ends = np.append(starts[1:], len(r)) - 1
        vv = v[ends]
    else:
        raise ValueError(f"unknown collision op {op!r}")
    return r[starts], c[starts], vv


def csr_from_coo(r: np.ndarray, c: np.ndarray, v: np.ndarray, n_rows: int):
    """(indptr, cols, vals) — assumes coalesced, row-major-sorted input."""
    counts = np.bincount(r, minlength=n_rows)
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, c, v


def _segment_arange(counts: np.ndarray) -> np.ndarray:
    """[0..c0-1, 0..c1-1, ...] for counts ci, vectorized."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(counts)
    starts = ends - counts
    return np.arange(total, dtype=np.int64) - np.repeat(starts, counts)


def spgemm(a: Coo, b: Coo, n_inner: int) -> Coo:
    """C = A @ B for COO operands; inner dimension size ``n_inner``.

    Join A's column index against B's row index through B's CSR indptr,
    expand all products, then coalesce with sum — the classic expand/
    sort/contract SpGEMM, fully vectorized.
    """
    ar, ac, av = a
    br, bc, bv = b
    if len(ar) == 0 or len(br) == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0, dtype=np.float64)
    indptr, bcols, bvals = csr_from_coo(br, bc, bv, n_inner)
    starts = indptr[ac]
    counts = indptr[ac + 1] - starts
    b_idx = np.repeat(starts, counts) + _segment_arange(counts)
    out_r = np.repeat(ar, counts)
    out_c = bcols[b_idx]
    out_v = np.repeat(av, counts) * bvals[b_idx]
    return coalesce(out_r, out_c, out_v, "sum")


def spmv(a: Coo, x: np.ndarray) -> np.ndarray:
    """y = A @ x with dense x; returns dense y sized by max row index + 1."""
    ar, ac, av = a
    n = int(ar.max()) + 1 if len(ar) else 0
    y = np.zeros(n, dtype=np.float64)
    np.add.at(y, ar, av * x[ac])
    return y


def union_keys(a: np.ndarray, b: np.ndarray):
    """Union of two sorted unique key arrays + index maps into the union."""
    u = np.union1d(a, b)
    return u, np.searchsorted(u, a), np.searchsorted(u, b)


def intersect_maps(a: np.ndarray, b: np.ndarray):
    """Intersection of sorted unique arrays + positions in each operand."""
    inter, ia, ib = np.intersect1d(a, b, assume_unique=True, return_indices=True)
    return inter, ia, ib
