# The paper's primary contribution: associative arrays (core/assoc.py) and
# the string-interning boundary (core/dictionary.py). The database layer
# built on top of these lives in repro.db.
from .assoc import Assoc, split_str
from .dictionary import StringDict

__all__ = ["Assoc", "StringDict", "split_str"]
