"""Associative arrays — the paper's core data structure (paper §II).

An :class:`Assoc` maps pairs of string keys to string or numeric values,
behaves like a sparse matrix over sorted-unique key sets, and supports the
composable indexing and algebra from the paper:

    A['alice,', :]          row query            A['alice,bob,', :]
    A['al*,', :]            prefix query         A['alice,:,bob,', :]  range
    A[1:2, :]               positional           A == 47.0             filter
    A + B   A - B   A & B   A | B   A * B        (results are Assocs)

Conventions (matching D4M/D4M.jl):
  * A string selector's **last character is the delimiter** — 'a,b,' is the
    list ['a', 'b'].
  * String values are dictionary-encoded: ``val`` holds sorted-unique value
    strings and the numeric payload stores 1-based ids into it.
  * Arithmetic on string-valued arrays operates on the logical pattern
    (``logical()`` is applied first), as in D4M.
  * Duplicate (row, col) construction entries collapse with ``func``
    (default: numeric sum — MATLAB ``sparse()`` semantics; strings: min).
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from . import sparsegemm as sg

__all__ = ["Assoc", "split_str"]


def split_str(s: str) -> np.ndarray:
    """Split a D4M-style delimited string; the last char is the delimiter."""
    if len(s) == 0:
        return np.zeros(0, dtype=object)
    sep = s[-1]
    parts = s.split(sep)[:-1]
    return np.asarray(parts, dtype=object)


def _as_key_array(x) -> np.ndarray:
    """Normalize row/col constructor input to an object array of str."""
    if isinstance(x, str):
        return split_str(x)
    if isinstance(x, (int, float)):
        return np.asarray([str(x)], dtype=object)
    arr = np.asarray(x, dtype=object)
    if arr.ndim == 0:
        arr = arr[None]
    return np.asarray([str(e) for e in arr.ravel()], dtype=object)


def _as_val_array(x) -> Tuple[np.ndarray, bool]:
    """Normalize values; returns (array, is_numeric)."""
    if isinstance(x, str):
        return split_str(x), False
    if isinstance(x, (int, float, np.integer, np.floating)):
        return np.asarray([x], dtype=np.float64), True
    arr = np.asarray(x)
    if arr.ndim == 0:
        arr = arr[None]
    if arr.dtype.kind in "ifub":
        return arr.astype(np.float64).ravel(), True
    return np.asarray([str(e) for e in arr.ravel()], dtype=object), False


def _condense(keys: np.ndarray, idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Drop unreferenced keys; remap indices. keys sorted unique."""
    used = np.unique(idx)
    return keys[used], np.searchsorted(used, idx)


class Assoc:
    """Sparse associative array over sorted-unique string key sets."""

    __hash__ = object.__hash__  # __eq__ is a query operator, keep hashable

    def __init__(self, row="", col="", val=1.0, func: Optional[str] = None):
        rows = _as_key_array(row)
        cols = _as_key_array(col)
        vals, numeric = _as_val_array(val)
        if len(rows) == 0 or len(cols) == 0 or len(vals) == 0:
            rows = np.zeros(0, dtype=object)
            cols = np.zeros(0, dtype=object)
            vals = np.zeros(0, dtype=np.float64) if numeric else np.zeros(0, object)
        n = max(len(rows), len(cols), len(vals))
        if len(rows) not in (1, n) or len(cols) not in (1, n) or len(vals) not in (1, n):
            raise ValueError(
                f"length mismatch: rows={len(rows)} cols={len(cols)} vals={len(vals)}"
            )
        if n and len(rows) == 1:
            rows = np.repeat(rows, n)
        if n and len(cols) == 1:
            cols = np.repeat(cols, n)
        if n and len(vals) == 1:
            vals = np.repeat(vals, n)

        if numeric:
            keep = vals != 0.0
            rows, cols, vals = rows[keep], cols[keep], vals[keep]
            n = len(rows)

        self.row, r = np.unique(rows, return_inverse=True)
        self.col, c = np.unique(cols, return_inverse=True)
        if numeric:
            self.val = None
            v = vals
        else:
            self.val, vi = np.unique(vals, return_inverse=True)
            v = (vi + 1).astype(np.float64)  # 1-based ids, D4M style
        func = func or ("sum" if numeric else "min")
        r, c, v = sg.coalesce(r.astype(np.int64), c.astype(np.int64), v, func)
        self.r, self.c, self.v = r, c, v
        if not numeric:
            self._condense_vals()
        else:
            self._drop_zeros()
        self._condense_keys()

    # ------------------------------------------------------------- internals
    @classmethod
    def _from_parts(cls, row, col, val, r, c, v) -> "Assoc":
        a = cls.__new__(cls)
        a.row, a.col, a.val = row, col, val
        a.r, a.c, a.v = r.astype(np.int64), c.astype(np.int64), v.astype(np.float64)
        a._condense_keys()
        if a.val is None:
            a._drop_zeros()
        else:
            a._condense_vals()
        return a

    def _drop_zeros(self) -> None:
        keep = self.v != 0.0
        if not keep.all():
            self.r, self.c, self.v = self.r[keep], self.c[keep], self.v[keep]
            self._condense_keys(force=True)

    def _condense_keys(self, force: bool = False) -> None:
        if len(self.r) == 0:
            self.row = self.row[:0]
            self.col = self.col[:0]
            return
        if force or len(np.unique(self.r)) != len(self.row):
            self.row, self.r = _condense(self.row, self.r)
        if force or len(np.unique(self.c)) != len(self.col):
            self.col, self.c = _condense(self.col, self.c)

    def _condense_vals(self) -> None:
        if self.val is None:
            return
        ids = self.v.astype(np.int64) - 1
        used = np.unique(ids)
        if len(used) != len(self.val):
            self.val = self.val[used]
            self.v = (np.searchsorted(used, ids) + 1).astype(np.float64)

    # ------------------------------------------------------------ properties
    @property
    def shape(self) -> Tuple[int, int]:
        return (len(self.row), len(self.col))

    def nnz(self) -> int:
        return len(self.v)

    def is_numeric(self) -> bool:
        return self.val is None

    def triples(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(row_strs, col_strs, values) — values str array in string mode."""
        rows = self.row[self.r]
        cols = self.col[self.c]
        if self.val is None:
            return rows, cols, self.v.copy()
        return rows, cols, self.val[self.v.astype(np.int64) - 1]

    find = triples

    def getval(self) -> np.ndarray:
        return self.v.copy() if self.val is None else self.val.copy()

    def logical(self) -> "Assoc":
        """Pattern of the array: every stored entry becomes 1.0 (numeric)."""
        return Assoc._from_parts(
            self.row.copy(), self.col.copy(), None,
            self.r.copy(), self.c.copy(), np.ones(len(self.v)),
        )

    def to_dense(self) -> np.ndarray:
        d = np.zeros(self.shape)
        d[self.r, self.c] = self.v
        return d

    def transpose(self) -> "Assoc":
        order = np.lexsort((self.r, self.c))
        return Assoc._from_parts(
            self.col.copy(), self.row.copy(),
            None if self.val is None else self.val.copy(),
            self.c[order], self.r[order], self.v[order],
        )

    @property
    def T(self) -> "Assoc":
        return self.transpose()

    # ------------------------------------------------------------- indexing
    def _resolve(self, sel, keys: np.ndarray) -> np.ndarray:
        """Selector -> sorted array of indices into ``keys``."""
        n = len(keys)
        if sel is None or (isinstance(sel, slice) and sel == slice(None)):
            return np.arange(n, dtype=np.int64)
        if isinstance(sel, str) and sel == ":":
            return np.arange(n, dtype=np.int64)
        if isinstance(sel, slice):  # positional
            return np.arange(n, dtype=np.int64)[sel]
        if isinstance(sel, (int, np.integer)):
            return np.asarray([sel], dtype=np.int64)
        if isinstance(sel, str):
            toks = split_str(sel)
        else:
            arr = np.asarray(sel)
            if arr.dtype.kind in "iu":
                return arr.astype(np.int64).ravel()
            toks = np.asarray([str(t) for t in arr.ravel()], dtype=object)
        if len(toks) == 3 and toks[1] == ":":  # 'a,:,b,' range (inclusive)
            lo = np.searchsorted(keys, toks[0], side="left")
            hi = np.searchsorted(keys, toks[2], side="right")
            return np.arange(lo, hi, dtype=np.int64)
        out = []
        for t in toks:
            if t.endswith("*"):  # prefix glob
                pre = t[:-1]
                lo = np.searchsorted(keys, pre, side="left")
                hi = np.searchsorted(keys, pre + "￿", side="right")
                out.append(np.arange(lo, hi, dtype=np.int64))
            else:
                i = np.searchsorted(keys, t)
                if i < n and keys[i] == t:
                    out.append(np.asarray([i], dtype=np.int64))
        if not out:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate(out))

    def __getitem__(self, key) -> "Assoc":
        if not isinstance(key, tuple) or len(key) != 2:
            raise TypeError("Assoc indexing is 2-D: a[rows, cols]")
        rsel, csel = key
        ri = self._resolve(rsel, self.row)
        ci = self._resolve(csel, self.col)
        mask = np.isin(self.r, ri) & np.isin(self.c, ci)
        return Assoc._from_parts(
            self.row.copy(), self.col.copy(),
            None if self.val is None else self.val.copy(),
            self.r[mask], self.c[mask], self.v[mask],
        )

    # ----------------------------------------------------- value comparisons
    def _value_mask(self, op, other) -> "Assoc":
        if isinstance(other, str):
            if self.val is None:
                vals = np.asarray([str(x) for x in self.v], dtype=object)
            else:
                vals = self.val[self.v.astype(np.int64) - 1]
            mask = op(vals, other)
        else:
            if self.val is not None:
                raise TypeError("numeric comparison on string-valued Assoc")
            mask = op(self.v, other)
        return Assoc._from_parts(
            self.row.copy(), self.col.copy(),
            None if self.val is None else self.val.copy(),
            self.r[mask], self.c[mask], self.v[mask],
        )

    def __eq__(self, other):  # noqa: D105 — D4M query operator
        if isinstance(other, Assoc):
            return self._elementwise_equal(other)
        return self._value_mask(lambda a, b: a == b, other)

    def __ne__(self, other):
        if isinstance(other, Assoc):
            raise TypeError("use same_as() for structural comparison")
        return self._value_mask(lambda a, b: a != b, other)

    def __gt__(self, other):
        return self._value_mask(lambda a, b: a > b, other)

    def __ge__(self, other):
        return self._value_mask(lambda a, b: a >= b, other)

    def __lt__(self, other):
        return self._value_mask(lambda a, b: a < b, other)

    def __le__(self, other):
        return self._value_mask(lambda a, b: a <= b, other)

    def _elementwise_equal(self, other: "Assoc") -> "Assoc":
        ar, ac, av = self.triples()
        br, bc, bv = other.triples()
        mine = {(r, c): v for r, c, v in zip(ar, ac, av)}
        keep_r, keep_c = [], []
        for r, c, v in zip(br, bc, bv):
            w = mine.get((r, c))
            if w is not None and w == v:
                keep_r.append(r)
                keep_c.append(c)
        if not keep_r:
            return Assoc()
        return Assoc(np.asarray(keep_r, object), np.asarray(keep_c, object), 1.0)

    def same_as(self, other: "Assoc") -> bool:
        """Structural equality (keys, pattern, values)."""
        if self.shape != other.shape or self.nnz() != other.nnz():
            return False
        a, b = self.triples(), other.triples()
        return all(np.array_equal(x, y) for x, y in zip(a, b))

    # -------------------------------------------------------------- algebra
    def _numeric(self) -> "Assoc":
        return self if self.val is None else self.logical()

    def _aligned_coo(self, other: "Assoc"):
        a, b = self._numeric(), other._numeric()
        urow, ra, rb = sg.union_keys(a.row, b.row)
        ucol, ca, cb = sg.union_keys(a.col, b.col)
        r = np.concatenate([ra[a.r], rb[b.r]])
        c = np.concatenate([ca[a.c], cb[b.c]])
        v = np.concatenate([a.v, b.v])
        both = np.concatenate([np.ones(len(a.v)), np.ones(len(b.v))])
        return urow, ucol, r, c, v, both

    def __add__(self, other: "Assoc") -> "Assoc":
        urow, ucol, r, c, v, _ = self._aligned_coo(other)
        r, c, v = sg.coalesce(r, c, v, "sum")
        return Assoc._from_parts(urow, ucol, None, r, c, v)

    def __sub__(self, other: "Assoc") -> "Assoc":
        b = other._numeric()
        neg = Assoc._from_parts(b.row.copy(), b.col.copy(), None, b.r, b.c, -b.v)
        return self + neg

    def __or__(self, other: "Assoc") -> "Assoc":
        urow, ucol, r, c, v, _ = self._aligned_coo(other)
        r, c, v = sg.coalesce(r, c, v, "max")
        return Assoc._from_parts(urow, ucol, None, r, c, v)

    def __and__(self, other: "Assoc") -> "Assoc":
        urow, ucol, r, c, v, cnt = self._aligned_coo(other)
        rm, cm, vm = sg.coalesce(r, c, v, "min")
        _, _, n = sg.coalesce(r, c, cnt, "sum")
        keep = n >= 2.0  # present in both operands
        return Assoc._from_parts(urow, ucol, None, rm[keep], cm[keep], vm[keep])

    def __mul__(self, other):
        if isinstance(other, (int, float, np.floating, np.integer)):
            a = self._numeric()
            return Assoc._from_parts(
                a.row.copy(), a.col.copy(), None, a.r, a.c, a.v * float(other)
            )
        a, b = self._numeric(), other._numeric()
        inner, ia, ib = sg.intersect_maps(a.col, b.row)
        if len(inner) == 0 or a.nnz() == 0 or b.nnz() == 0:
            return Assoc()
        # remap both operands into the shared inner index space
        amask = np.isin(a.c, ia)
        bmask = np.isin(b.r, ib)
        a_inner = np.searchsorted(ia, a.c[amask])
        b_inner = np.searchsorted(ib, b.r[bmask])
        order = np.lexsort((np.zeros(bmask.sum(), np.int64), b_inner))
        rr, cc, vv = sg.spgemm(
            (a.r[amask], a_inner, a.v[amask]),
            (b_inner[order], b.c[bmask][order], b.v[bmask][order]),
            len(inner),
        )
        return Assoc._from_parts(a.row.copy(), b.col.copy(), None, rr, cc, vv)

    __rmul__ = __mul__

    def sum(self, axis: Optional[int] = None, key: str = "sum"):
        """Numeric sum; axis=None -> scalar, 1 -> per-row, 0 -> per-col."""
        a = self._numeric()
        if axis is None:
            return float(a.v.sum())
        k = np.asarray([key], dtype=object)  # literal key, no delimiter split
        if axis == 1:
            tot = np.zeros(len(a.row))
            np.add.at(tot, a.r, a.v)
            return Assoc(a.row, k, tot)
        tot = np.zeros(len(a.col))
        np.add.at(tot, a.c, a.v)
        return Assoc(k, a.col, tot)

    # ------------------------------------------------------------- printing
    def __repr__(self) -> str:
        r, c, v = self.triples()
        lines = [f"Assoc {self.shape[0]}x{self.shape[1]} nnz={self.nnz()}"]
        for i in range(min(len(r), 16)):
            lines.append(f"  ({r[i]!r}, {c[i]!r}) -> {v[i]!r}")
        if len(r) > 16:
            lines.append(f"  ... {len(r) - 16} more")
        return "\n".join(lines)

    def printfull(self) -> str:
        r, c, _ = self.triples()
        out = [" " * 12 + " ".join(f"{k:>10}" for k in self.col)]
        d = self.to_dense() if self.val is None else None
        for i, rk in enumerate(self.row):
            cells = []
            for j in range(len(self.col)):
                if d is not None:
                    cells.append(f"{d[i, j]:>10g}" if d[i, j] else " " * 10)
                else:
                    m = (self.r == i) & (self.c == j)
                    cells.append(
                        f"{self.val[int(self.v[m][0]) - 1]:>10}" if m.any() else " " * 10
                    )
            out.append(f"{rk:>12}" + " ".join(cells))
        return "\n".join(out)
