"""Batched serving engine: continuous prefill + decode over a request queue.

Small-model CPU serving for examples/serve_lm.py and the serve smoke tests;
the same step functions lower onto the production mesh via launch/dryrun
(decode_32k / long_500k cells)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model
from ..models import transformer


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # int32 [S]
    max_new: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    """Fixed-batch engine: pads requests to slots, prefills per batch, then
    decodes until every slot finishes (greedy)."""

    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256):
        assert model.cfg.family in ("dense", "moe"), \
            "engine demo targets decoder-only LMs"
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        sh = lambda x, *a: x  # noqa: E731 — single-device serving
        cfg = model.cfg

        def _prefill(params, tokens):
            return transformer.prefill(cfg, params, tokens, sh,
                                       max_len=max_len)

        def _decode(params, token, cache, pos):
            return transformer.decode_step(cfg, params, token, cache, pos, sh)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def run(self, requests: List[Request]) -> dict:
        stats = {"tokens_out": 0, "wall_s": 0.0, "batches": 0}
        t0 = time.time()
        for i in range(0, len(requests), self.slots):
            batch = requests[i:i + self.slots]
            self._run_batch(batch, stats)
            stats["batches"] += 1
        stats["wall_s"] = time.time() - t0
        stats["tok_per_s"] = stats["tokens_out"] / max(stats["wall_s"], 1e-9)
        return stats

    def _run_batch(self, batch: List[Request], stats: dict) -> None:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((self.slots, plen), np.int32)
        for j, r in enumerate(batch):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        new = np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)
        outs = [[int(new[j])] for j in range(b)]
        max_new = max(r.max_new for r in batch)
        pos = plen
        for _ in range(max_new - 1):
            if pos >= self.max_len:
                break
            logits, cache = self._decode(
                self.params, jnp.asarray(new[:, None]), cache,
                jnp.asarray(pos, jnp.int32))
            new = np.asarray(jnp.argmax(logits[:, -1, :], -1), np.int32)
            for j in range(b):
                if len(outs[j]) < batch[j].max_new:
                    outs[j].append(int(new[j]))
            pos += 1
        for j, r in enumerate(batch):
            r.out = np.asarray(outs[j], np.int32)
            stats["tokens_out"] += len(r.out)
