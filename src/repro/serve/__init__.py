from .engine import Engine, Request

__all__ = ["Engine", "Request"]
