from .ops import kway_merge, merge_combine_rows, merge_sorted
from .ref import merge_combine_rows_ref, merge_sorted_ref, row_rank_ref

__all__ = ["kway_merge", "merge_combine_rows", "merge_combine_rows_ref",
           "merge_sorted", "merge_sorted_ref", "row_rank_ref"]
