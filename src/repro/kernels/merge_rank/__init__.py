from .ops import merge_sorted
from .ref import merge_sorted_ref

__all__ = ["merge_sorted", "merge_sorted_ref"]
