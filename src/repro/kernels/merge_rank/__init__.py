from .ops import kway_merge, merge_sorted
from .ref import merge_sorted_ref

__all__ = ["kway_merge", "merge_sorted", "merge_sorted_ref"]
