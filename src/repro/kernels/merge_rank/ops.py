"""jit'd wrapper: merge two sorted (row, col, val) runs by rank + scatter.

Invalid entries in either run must carry key (I32_MAX, I32_MAX); they sort
to the tail of the merged output naturally, so fixed-capacity tablets merge
without knowing their valid counts inside the kernel.
"""
import functools

import jax
import jax.numpy as jnp

from ..common import INTERPRET, I32_MAX, pad_to
from .kernel import pair_rank_pallas


@functools.partial(jax.jit, static_argnames=("block_q", "block_t", "interpret"))
def merge_sorted(ar, ac, av, br, bc, bv, block_q: int = 256,
                 block_t: int = 2048, interpret: bool = INTERPRET):
    """Merge sorted runs A and B (each sorted lex by (r, c), pads = I32_MAX).

    Returns (r, c, v) of length len(A)+len(B); valid entries first in sorted
    order, A-side entries preceding B-side entries on equal keys (so a later
    dedup pass can implement last-wins for the newer B side).
    """
    n_a, n_b = ar.shape[0], br.shape[0]
    ar_p, _ = pad_to(ar.astype(jnp.int32), block_q, 0, I32_MAX)
    ac_p, _ = pad_to(ac.astype(jnp.int32), block_q, 0, I32_MAX)
    br_p, _ = pad_to(br.astype(jnp.int32), block_q, 0, I32_MAX)
    bc_p, _ = pad_to(bc.astype(jnp.int32), block_q, 0, I32_MAX)
    at_r, _ = pad_to(ar.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    at_c, _ = pad_to(ac.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    bt_r, _ = pad_to(br.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    bt_c, _ = pad_to(bc.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)

    rank_a = pair_rank_pallas(bt_r, bt_c, ar_p.reshape(-1, 1), ac_p.reshape(-1, 1),
                              strict=True, block_q=block_q, block_t=block_t,
                              interpret=interpret)[: n_a, 0]
    rank_b = pair_rank_pallas(at_r, at_c, br_p.reshape(-1, 1), bc_p.reshape(-1, 1),
                              strict=False, block_q=block_q, block_t=block_t,
                              interpret=interpret)[: n_b, 0]
    # rank counts include the other side's I32_MAX pads only for pad queries,
    # which always land at/after position len(valid A)+len(valid B).
    pos_a = jnp.minimum(jnp.arange(n_a, dtype=jnp.int32) + rank_a, n_a + n_b - 1)
    pos_b = jnp.minimum(jnp.arange(n_b, dtype=jnp.int32) + rank_b, n_a + n_b - 1)

    out_r = jnp.full((n_a + n_b,), I32_MAX, dtype=jnp.int32)
    out_c = jnp.full((n_a + n_b,), I32_MAX, dtype=jnp.int32)
    out_v = jnp.zeros((n_a + n_b,), dtype=av.dtype)
    # scatter pads first is unnecessary: pad positions are disjoint from
    # valid positions; among-pad collisions are harmless (pad over pad).
    out_r = out_r.at[pos_b].set(br.astype(jnp.int32)).at[pos_a].set(ar.astype(jnp.int32))
    out_c = out_c.at[pos_b].set(bc.astype(jnp.int32)).at[pos_a].set(ac.astype(jnp.int32))
    out_v = out_v.at[pos_b].set(bv).at[pos_a].set(av)
    # valid A entries can never share a slot with valid B entries; pads from
    # A (written last) may overwrite pads from B — both are I32_MAX, fine.
    return out_r, out_c, out_v


def kway_merge(runs, use_pallas: bool = True, interpret: bool = INTERPRET):
    """Merge k sorted runs into one by pairwise reduction (major compaction).

    ``runs`` is a list of (rows, cols, vals) triples sorted lex by (r, c)
    with I32_MAX key pads, ordered OLDEST FIRST. Each pairwise merge keeps
    the left (older) side first on equal keys, and the tree reduction only
    ever merges a prefix-contiguous older group with a newer one, so the
    merged output preserves global age order within every equal-key group.
    A single downstream dedup pass therefore implements every Accumulo
    combiner in ``db.iterators`` (last = newest wins, sum/min/max see all
    contributions exactly once).

    Returns (rows, cols, vals) of length sum(len(run)); valid entries first.
    """
    if not runs:
        raise ValueError("kway_merge needs at least one run")
    merge = merge_sorted if use_pallas else _merge_ref
    runs = list(runs)
    while len(runs) > 1:
        nxt = [
            merge(*runs[i], *runs[i + 1], **(
                {"interpret": interpret} if use_pallas else {}))
            for i in range(0, len(runs) - 1, 2)
        ]
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]


def _merge_ref(ar, ac, av, br, bc, bv):
    from .ref import merge_sorted_ref
    return merge_sorted_ref(ar, ac, av, br, bc, bv)
