"""jit'd wrapper: merge sorted runs by rank + scatter (1-D) / one-hot (2-D).

Invalid entries in either run must carry key (I32_MAX, I32_MAX); they sort
to the tail of the merged output naturally, so fixed-capacity tablets merge
without knowing their valid counts inside the kernel.
"""
import functools

import jax
import jax.numpy as jnp

from ..common import INTERPRET, I32_MAX, pad_to
from .kernel import pair_rank_pallas, row_rank_pallas


@functools.partial(jax.jit, static_argnames=("block_q", "block_t", "interpret"))
def merge_sorted(ar, ac, av, br, bc, bv, block_q: int = 256,
                 block_t: int = 2048, interpret: bool = INTERPRET):
    """Merge sorted runs A and B (each sorted lex by (r, c), pads = I32_MAX).

    Returns (r, c, v) of length len(A)+len(B); valid entries first in sorted
    order, A-side entries preceding B-side entries on equal keys (so a later
    dedup pass can implement last-wins for the newer B side).
    """
    n_a, n_b = ar.shape[0], br.shape[0]
    ar_p, _ = pad_to(ar.astype(jnp.int32), block_q, 0, I32_MAX)
    ac_p, _ = pad_to(ac.astype(jnp.int32), block_q, 0, I32_MAX)
    br_p, _ = pad_to(br.astype(jnp.int32), block_q, 0, I32_MAX)
    bc_p, _ = pad_to(bc.astype(jnp.int32), block_q, 0, I32_MAX)
    at_r, _ = pad_to(ar.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    at_c, _ = pad_to(ac.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    bt_r, _ = pad_to(br.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    bt_c, _ = pad_to(bc.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)

    rank_a = pair_rank_pallas(bt_r, bt_c, ar_p.reshape(-1, 1), ac_p.reshape(-1, 1),
                              strict=True, block_q=block_q, block_t=block_t,
                              interpret=interpret)[: n_a, 0]
    rank_b = pair_rank_pallas(at_r, at_c, br_p.reshape(-1, 1), bc_p.reshape(-1, 1),
                              strict=False, block_q=block_q, block_t=block_t,
                              interpret=interpret)[: n_b, 0]
    # rank counts include the other side's I32_MAX pads only for pad queries,
    # which always land at/after position len(valid A)+len(valid B).
    pos_a = jnp.minimum(jnp.arange(n_a, dtype=jnp.int32) + rank_a, n_a + n_b - 1)
    pos_b = jnp.minimum(jnp.arange(n_b, dtype=jnp.int32) + rank_b, n_a + n_b - 1)

    out_r = jnp.full((n_a + n_b,), I32_MAX, dtype=jnp.int32)
    out_c = jnp.full((n_a + n_b,), I32_MAX, dtype=jnp.int32)
    out_v = jnp.zeros((n_a + n_b,), dtype=av.dtype)
    # scatter pads first is unnecessary: pad positions are disjoint from
    # valid positions; among-pad collisions are harmless (pad over pad).
    out_r = out_r.at[pos_b].set(br.astype(jnp.int32)).at[pos_a].set(ar.astype(jnp.int32))
    out_c = out_c.at[pos_b].set(bc.astype(jnp.int32)).at[pos_a].set(ac.astype(jnp.int32))
    out_v = out_v.at[pos_b].set(bv).at[pos_a].set(av)
    # valid A entries can never share a slot with valid B entries; pads from
    # A (written last) may overwrite pads from B — both are I32_MAX, fine.
    return out_r, out_c, out_v


def kway_merge(runs, use_pallas: bool = True, interpret: bool = INTERPRET):
    """Merge k sorted runs into one by pairwise reduction (major compaction).

    ``runs`` is a list of (rows, cols, vals) triples sorted lex by (r, c)
    with I32_MAX key pads, ordered OLDEST FIRST. Each pairwise merge keeps
    the left (older) side first on equal keys, and the tree reduction only
    ever merges a prefix-contiguous older group with a newer one, so the
    merged output preserves global age order within every equal-key group.
    A single downstream dedup pass therefore implements every Accumulo
    combiner in ``db.iterators`` (last = newest wins, sum/min/max see all
    contributions exactly once).

    Returns (rows, cols, vals) of length sum(len(run)); valid entries first.
    """
    if not runs:
        raise ValueError("kway_merge needs at least one run")
    merge = merge_sorted if use_pallas else _merge_ref
    runs = list(runs)
    while len(runs) > 1:
        nxt = [
            merge(*runs[i], *runs[i + 1], **(
                {"interpret": interpret} if use_pallas else {}))
            for i in range(0, len(runs) - 1, 2)
        ]
        if len(runs) % 2:
            nxt.append(runs[-1])
        runs = nxt
    return runs[0]


def _merge_ref(ar, ac, av, br, bc, bv):
    from .ref import merge_sorted_ref
    return merge_sorted_ref(ar, ac, av, br, bc, bv)


def merge_combine_rows(keys, vals, use_pallas: bool = False,
                       block_q: int = 8, block_t: int = 128,
                       interpret: bool = INTERPRET):
    """Row-wise K-way merge-combine by rank + scatter (traced inline —
    callers jit). The batched read-path variant of ``merge_sorted``:

    ``keys`` int32 [Q, N] — each row is the CONCATENATION of K sorted
    candidate segments (one per run, (col, age)-packed by the fused query
    so valid keys are globally unique per row); pads carry I32_MAX.
    ``vals`` [Q, N] rides along. Returns (keys, vals) with every row in
    ascending key order, pads at the tail.

    Because valid keys are unique per row, an element's strict self-rank
    against its whole row IS its merged position — the K-way
    generalization of ``merge_sorted``'s rank-in-the-other-run scheme,
    collapsed to a single rank pass (no pairwise reduction tree). The
    permutation is applied as a ONE-HOT contraction rather than a
    scatter: XLA:CPU lowers 2-D scatters to a slow serialized loop
    (~1 ms for a [512, 20] tile) while the rank == position one-hot
    einsum vectorizes (~3.5x faster, same asymptotics as the rank pass
    itself). Pads all rank at n_valid and are masked out of the one-hot,
    so unfilled output slots take I32_MAX (keys) / 0 (vals). Cost is N^2
    branch-free compares per row vs the sort's N log N comparator ops —
    a win for the small candidate widths the fused read path produces
    (XLA:CPU comparator sorts are scalar and branchy; the compare tensor
    vectorizes).
    """
    n_q, n_w = keys.shape
    if use_pallas:
        qp, wp = -n_q % block_q, -n_w % block_t
        kp = jnp.pad(keys, ((0, qp), (0, wp)), constant_values=I32_MAX)
        rank = row_rank_pallas(kp, block_q=block_q, block_t=block_t,
                               interpret=interpret)[:n_q, :n_w]
    else:
        from .ref import row_rank_ref
        rank = row_rank_ref(keys)
    valid = keys != I32_MAX
    iota = jnp.arange(n_w, dtype=jnp.int32)
    onehot = ((rank[:, :, None] == iota[None, None, :])
              & valid[:, :, None])                       # [Q, src, dst]
    ohi = onehot.astype(jnp.int32)
    filled = jnp.sum(ohi, axis=1)                        # [Q, dst] in {0,1}
    out_k = (jnp.einsum("qj,qjp->qp", jnp.where(valid, keys, 0), ohi)
             + (1 - filled) * I32_MAX)
    out_v = jnp.einsum("qj,qjp->qp", jnp.where(valid, vals, 0),
                       onehot.astype(vals.dtype))
    return out_k, out_v
