"""Pure-jnp oracle: stable merge of two (row, col, val) sorted runs."""
import jax.numpy as jnp


def merge_sorted_ref(ar, ac, av, br, bc, bv):
    """Concatenate + stable lexicographic sort (A entries precede ties)."""
    r = jnp.concatenate([ar, br])
    c = jnp.concatenate([ac, bc])
    v = jnp.concatenate([av, bv])
    side = jnp.concatenate([jnp.zeros_like(ar), jnp.ones_like(br)])
    order = jnp.lexsort((side, c, r))
    return r[order], c[order], v[order]
