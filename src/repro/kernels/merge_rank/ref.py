"""Pure-jnp oracle: stable merge of two (row, col, val) sorted runs."""
import jax.numpy as jnp


def merge_sorted_ref(ar, ac, av, br, bc, bv):
    """Concatenate + stable lexicographic sort (A entries precede ties)."""
    r = jnp.concatenate([ar, br])
    c = jnp.concatenate([ac, bc])
    v = jnp.concatenate([av, bv])
    side = jnp.concatenate([jnp.zeros_like(ar), jnp.ones_like(br)])
    order = jnp.lexsort((side, c, r))
    return r[order], c[order], v[order]


def row_rank_ref(keys):
    """Branch-free per-row strict self-rank (the ``row_rank_pallas``
    oracle): ``o[i, j] = |{ k : keys[i, k] < keys[i, j] }|``."""
    return jnp.sum(keys[:, None, :] < keys[:, :, None], axis=2,
                   dtype=jnp.int32)


def merge_combine_rows_ref(keys, vals):
    """Sort-based oracle for ``merge_combine_rows``: row-wise ascending
    key order with vals carried along (valid keys unique per row, so
    stability is irrelevant everywhere except among I32_MAX pads — whose
    vals are garbage either way)."""
    order = jnp.argsort(keys, axis=1)
    return (jnp.take_along_axis(keys, order, axis=1),
            jnp.take_along_axis(vals, order, axis=1))
