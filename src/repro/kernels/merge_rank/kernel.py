"""Merge-rank kernel — ingest (minor-compaction) hot path.

Merging the sorted memtable batch into the tablet's sorted run is Accumulo's
minor compaction. Sequential two-pointer merge is a CPU idiom; the TPU
adaptation computes each element's *rank in the other run* with VMEM-tiled
branch-free lexicographic compares (same structure as sorted_search, but on
(row, col) key pairs):

    merged_pos(a_i) = i + |{ b : b <  a_i }|      (strict)
    merged_pos(b_j) = j + |{ a : a <= b_j }|      (non-strict, keeps A-side
                                                   entries first on ties so
                                                   the newer B side wins a
                                                   later dedup pass)
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_rank_kernel(qr_ref, qc_ref, tr_ref, tc_ref, o_ref, *, strict: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qr, qc = qr_ref[...], qc_ref[...]   # (bq, 1)
    tr, tc = tr_ref[...], tc_ref[...]   # (1, bt)
    second = (tc < qc) if strict else (tc <= qc)
    less = (tr < qr) | ((tr == qr) & second)
    o_ref[...] += jnp.sum(less.astype(jnp.int32), axis=1, keepdims=True)


def pair_rank_pallas(tr, tc, qr, qc, *, strict: bool,
                     block_q: int = 256, block_t: int = 2048,
                     interpret: bool = True):
    """Rank of each (qr, qc) pair within the sorted (tr, tc) run."""
    n_q, n_t = qr.shape[0], tr.shape[1]
    grid = (n_q // block_q, n_t // block_t)
    qspec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    tspec = pl.BlockSpec((1, block_t), lambda i, j: (0, j))
    return pl.pallas_call(
        functools.partial(_merge_rank_kernel, strict=strict),
        grid=grid,
        in_specs=[qspec, qspec, tspec, tspec],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, 1), jnp.int32),
        interpret=interpret,
    )(qr, qc, tr, tc)


def _row_rank_kernel(q_ref, t_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]                          # (bq, W) — full row of keys
    t = t_ref[...]                          # (bq, bt) — one target tile
    less = t[:, None, :] < q[:, :, None]    # (bq, W, bt) branch-free
    o_ref[...] += jnp.sum(less.astype(jnp.int32), axis=2)


def row_rank_pallas(keys, *, block_q: int = 8, block_t: int = 128,
                    interpret: bool = True):
    """Per-ROW self-rank: ``o[i, j] = |{ k : keys[i, k] < keys[i, j] }|``.

    The batched (query-axis) variant of ``pair_rank_pallas``: when each
    row holds the concatenation of K sorted segments whose valid keys are
    globally UNIQUE (pads = I32_MAX), the strict self-rank of an element
    IS its position in the K-way merged row — a rank+scatter merge of all
    K segments in one pass instead of a pairwise reduction tree. Pads all
    rank at n_valid (harmless scatter collisions, pad over pad).

    Shapes: keys [Q, W] with Q % block_q == 0 and W % block_t == 0
    (callers pad with I32_MAX — pad targets are never < any key, pad
    query rows rank to zeros; both slice away cleanly).
    """
    n_q, n_w = keys.shape
    grid = (n_q // block_q, n_w // block_t)
    return pl.pallas_call(
        _row_rank_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, n_w), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_q, block_t), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_q, n_w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, n_w), jnp.int32),
        interpret=interpret,
    )(keys, keys)
