"""Flash attention (forward) as a Pallas TPU kernel.

The jnp blocked attention in models/layers.py keeps the roofline analysis
transparent (Pallas custom calls are opaque to HLO cost analysis); THIS
kernel is the real-hardware hot path that eliminates the P-block HBM
traffic identified in EXPERIMENTS §Perf (scores/probabilities never leave
VMEM). Online-softmax accumulators live in the output refs, which persist
across the innermost (kv-block) grid dimension.

Grid = (batch·heads, q_blocks, kv_blocks); GQA is handled in the k/v index
maps (query head h reads kv head h // rep — no repeated KV tensor).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, qb: int, kb: int, nk: int,
                  q_offset: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0].astype(jnp.float32)              # (qb, hd)
    k = k_ref[0].astype(jnp.float32)              # (kb, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qi = pl.program_id(1)
        qpos = q_offset + qi * qb + jax.lax.broadcasted_iota(
            jnp.int32, (qb, kb), 0)
        kpos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        s = jnp.where(qpos >= kpos, s, -1e30)

    m_prev = m_ref[0]                             # (qb,)
    l_prev = l_ref[0]
    o_prev = o_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    o_new = o_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(j == nk - 1)
    def _final():
        o_ref[0] = o_new / jnp.maximum(l_new, 1e-30)[:, None]

    @pl.when(j != nk - 1)
    def _accum():
        o_ref[0] = o_new


def flash_attention_pallas(q, k, v, *, causal: bool, q_offset: int = 0,
                           qb: int = 256, kb: int = 256,
                           interpret: bool = True):
    """q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd] -> [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    kvh, sk = k.shape[2], k.shape[1]
    rep = h // kvh
    qb = min(qb, sq)
    kb = min(kb, sk)
    assert sq % qb == 0 and sk % kb == 0
    nq, nk = sq // qb, sk // kb
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, hd)

    def kv_index(bh, i, j):  # GQA: query head bh reads kv head (bh%h)//rep
        return (bh // h) * kvh + (bh % h) // rep, j, 0

    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, scale=hd ** -0.5, causal=causal,
                          qb=qb, kb=kb, nk=nk, q_offset=q_offset),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, kb, hd), kv_index),
            pl.BlockSpec((1, kb, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, qb, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, qb), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((1, qb), lambda bh, i, j: (bh, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq), jnp.float32),
            jax.ShapeDtypeStruct((b * h, sq), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)
