"""jit'd public wrapper for the flash attention kernel."""
import functools

import jax

from ..common import INTERPRET
from .kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "q_offset", "qb",
                                              "kb", "interpret"))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    qb: int = 256, kb: int = 256,
                    interpret: bool = INTERPRET):
    return flash_attention_pallas(q, k, v, causal=causal, q_offset=q_offset,
                                  qb=qb, kb=kb, interpret=interpret)
