"""Pure-jnp oracle for flash attention (naive SDPA, grouped GQA)."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool, q_offset: int = 0):
    b, sq, h, hd = q.shape
    kvh, sk = k.shape[2], k.shape[1]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, hd).astype(jnp.float32)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k.astype(jnp.float32))
    scores = scores / (hd ** 0.5)
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        mask = qpos >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
