"""jit'd public wrapper for the sorted_search kernel."""
import functools

import jax
import jax.numpy as jnp

from ..common import INTERPRET, I32_MAX, pad_to
from .kernel import rank_pallas, rank_pallas_batched


@functools.partial(jax.jit, static_argnames=("side", "block_q", "block_t", "interpret"))
def sorted_search(tab: jax.Array, q: jax.Array, side: str = "left",
                  block_q: int = 256, block_t: int = 2048,
                  interpret: bool = INTERPRET) -> jax.Array:
    """Vectorized searchsorted: positions of ``q`` in sorted 1-D ``tab``.

    ``tab`` must be padded with I32_MAX beyond its valid prefix (the pad
    never counts: every real query is < I32_MAX).
    """
    q2, n_q = pad_to(q.astype(jnp.int32).reshape(-1, 1), block_q, 0, 0)
    tab2, _ = pad_to(tab.astype(jnp.int32).reshape(1, -1), block_t, 1, I32_MAX)
    out = rank_pallas(tab2, q2, strict=(side == "left"),
                      block_q=block_q, block_t=block_t, interpret=interpret)
    return out[:n_q, 0]


@functools.partial(jax.jit, static_argnames=("side", "block_q", "block_t", "interpret"))
def sorted_search_batched(tabs: jax.Array, q: jax.Array, side: str = "left",
                          block_q: int = 256, block_t: int = 2048,
                          interpret: bool = INTERPRET) -> jax.Array:
    """Batched searchsorted: ranks of ``q`` in each row of ``tabs[K, N]``.

    Every row must be sorted and padded with I32_MAX past its valid prefix.
    One kernel launch covers all K runs — the fused LSM read path's rank
    search. Returns int32[K, Q].
    """
    q2, n_q = pad_to(q.astype(jnp.int32).reshape(-1, 1), block_q, 0, 0)
    tabs2, _ = pad_to(tabs.astype(jnp.int32), block_t, 1, I32_MAX)
    out = rank_pallas_batched(tabs2, q2, strict=(side == "left"),
                              block_q=block_q, block_t=block_t,
                              interpret=interpret)
    return out[:, :n_q]


@functools.partial(jax.jit, static_argnames=("block_q", "block_t",
                                             "interpret"))
def sorted_search_endpoints(tabs: jax.Array, lohi: jax.Array,
                            block_q: int = 256, block_t: int = 2048,
                            interpret: bool = INTERPRET):
    """Fence-to-fence endpoint ranks for a ``[lo, hi)`` range scan: the
    ``side='left'`` ranks of both endpoints in each row of ``tabs[K, N]``,
    in ONE kernel launch (``lohi`` is the length-2 [lo, hi] vector; ``hi``
    is exclusive, so both endpoints rank strictly). Returns
    (start[K], end[K]) int32 — the candidate window of each run.
    """
    out = sorted_search_batched(tabs, lohi, "left", block_q=block_q,
                                block_t=block_t, interpret=interpret)
    return out[:, 0], out[:, 1]
