from .ops import sorted_search
from .ref import sorted_search_ref

__all__ = ["sorted_search", "sorted_search_ref"]
