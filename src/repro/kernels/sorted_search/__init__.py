from .ops import (sorted_search, sorted_search_batched,
                  sorted_search_endpoints)
from .ref import sorted_search_batched_ref, sorted_search_ref

__all__ = ["sorted_search", "sorted_search_batched",
           "sorted_search_batched_ref", "sorted_search_endpoints",
           "sorted_search_ref"]
