"""Pure-jnp oracle for sorted_search."""
import jax.numpy as jnp


def sorted_search_ref(tab, n_valid, q, side: str = "left"):
    """searchsorted over the valid prefix of ``tab``."""
    return jnp.searchsorted(tab[:n_valid], q, side=side).astype(jnp.int32)
