"""Pure-jnp oracle for sorted_search."""
import jax.numpy as jnp


def sorted_search_ref(tab, n_valid, q, side: str = "left"):
    """searchsorted over the valid prefix of ``tab``."""
    return jnp.searchsorted(tab[:n_valid], q, side=side).astype(jnp.int32)


def sorted_search_batched_ref(tabs, q, side: str = "left"):
    """Per-run searchsorted over stacked I32_MAX-padded runs ``tabs[K, N]``.

    Pads count toward the rank only for queries >= I32_MAX, which real row
    ids never are — identical contract to the batched kernel.
    """
    import jax
    return jax.vmap(
        lambda t: jnp.searchsorted(t, q, side=side).astype(jnp.int32))(tabs)
