"""Vectorized rank search over a sorted table — the query hot path.

TPU adaptation of Accumulo's per-query binary search (paper §IV-B): branchy
log(N) probing is a CPU idiom; on TPU we compute
``lower_bound(q) = sum_tiles count(tile_elements < q)`` with VMEM-tiled
branch-free vector compares, embarrassingly parallel over queries and tiles.
Grid = (query_blocks, table_tiles); the table tile axis is the innermost
(sequential) grid dimension so the output block accumulates in place.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(q_ref, tab_ref, o_ref, *, strict: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]        # (bq, 1) int32
    t = tab_ref[...]      # (1, bt) int32
    cmp = (t < q) if strict else (t <= q)
    o_ref[...] += jnp.sum(cmp.astype(jnp.int32), axis=1, keepdims=True)


def rank_pallas(tab: jax.Array, q: jax.Array, *, strict: bool,
                block_q: int = 256, block_t: int = 2048,
                interpret: bool = True) -> jax.Array:
    """Ranks of ``q`` in sorted ``tab``. Inputs already padded to blocks.

    tab: (1, N) int32 sorted, padded with I32_MAX.
    q:   (Q, 1) int32.
    """
    n_q, n_t = q.shape[0], tab.shape[1]
    grid = (n_q // block_q, n_t // block_t)
    return pl.pallas_call(
        functools.partial(_rank_kernel, strict=strict),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, 1), jnp.int32),
        interpret=interpret,
    )(q, tab)


def _rank_batched_kernel(q_ref, tab_ref, o_ref, *, strict: bool):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]        # (bq, 1) int32 — shared across runs
    t = tab_ref[...]      # (1, bt) int32 — one tile of run k
    cmp = (t < q) if strict else (t <= q)
    o_ref[...] += jnp.sum(cmp.astype(jnp.int32), axis=1)[None, :]


def rank_pallas_batched(tabs: jax.Array, q: jax.Array, *, strict: bool,
                        block_q: int = 256, block_t: int = 2048,
                        interpret: bool = True) -> jax.Array:
    """Ranks of ``q`` in EACH of K stacked sorted runs — the fused LSM read
    path searches every resident run of a shard in one launch instead of K.

    Grid = (runs, query_blocks, table_tiles); the table tile axis stays the
    innermost (sequential) dimension so each (run, query-block) output block
    accumulates in place, exactly like the single-run kernel.

    tabs: (K, N) int32, each row sorted, padded with I32_MAX.
    q:    (Q, 1) int32.
    Returns (K, Q) int32 ranks.
    """
    n_k, n_t = tabs.shape
    n_q = q.shape[0]
    grid = (n_k, n_q // block_q, n_t // block_t)
    return pl.pallas_call(
        functools.partial(_rank_batched_kernel, strict=strict),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 1), lambda k, i, j: (i, 0)),
            pl.BlockSpec((1, block_t), lambda k, i, j: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda k, i, j: (k, i)),
        out_shape=jax.ShapeDtypeStruct((n_k, n_q), jnp.int32),
        interpret=interpret,
    )(q, tabs)
