"""Pure-jnp oracle for ELL SpMV."""
import jax.numpy as jnp


def spmv_ell_ref(cols, vals, x):
    """y[r] = sum_k vals[r,k] * x[cols[r,k]], entries with col < 0 dropped."""
    valid = cols >= 0
    xi = jnp.take(x, jnp.clip(cols, 0, x.shape[0] - 1))
    return jnp.sum(jnp.where(valid, vals * xi, 0.0), axis=1)
