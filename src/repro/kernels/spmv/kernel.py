"""Blocked-ELL SpMV kernel — associative-array matvec (graph BFS, Fig. 1).

The paper's point is that BFS *is* sparse matrix-vector multiply. CSR SpMV
with per-row pointer chasing is a CPU idiom; the TPU adaptation pads rows to
a fixed nnz/row (ELL), tiles x into VMEM, and accumulates per x-tile with
masked vectorized gathers — branch-free, fixed shapes.

Grid = (row_blocks, x_tiles), x-tile axis innermost for in-place accumulate.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, vals_ref, x_ref, o_ref, *, block_c: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = cols_ref[...]                  # (br, K) int32, pad = -1
    vals = vals_ref[...]                  # (br, K) f32
    x = x_ref[...]                        # (1, bc) f32
    local = cols - j * block_c
    in_tile = (local >= 0) & (local < block_c) & (cols >= 0)
    xi = jnp.take(x[0], jnp.clip(local, 0, block_c - 1))
    contrib = jnp.where(in_tile, vals * xi, 0.0)
    o_ref[...] += jnp.sum(contrib, axis=1, keepdims=True)


def spmv_ell_pallas(cols, vals, x, *, block_r: int = 256, block_c: int = 2048,
                    interpret: bool = True):
    """cols/vals: (R, K) ELL; x: (1, C); returns (R, 1) f32."""
    n_r, n_c = cols.shape[0], x.shape[1]
    grid = (n_r // block_r, n_c // block_c)
    return pl.pallas_call(
        functools.partial(_spmv_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, cols.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, cols.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_r, 1), jnp.float32),
        interpret=interpret,
    )(cols, vals, x)
