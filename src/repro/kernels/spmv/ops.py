"""jit'd public wrapper for blocked-ELL SpMV + CSR->ELL conversion."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..common import INTERPRET, pad_to
from .kernel import spmv_ell_pallas


def ell_from_coo(r: np.ndarray, c: np.ndarray, v: np.ndarray, n_rows: int):
    """Host-side COO (row-major sorted) -> ELL (cols, vals), pad col = -1."""
    counts = np.bincount(r, minlength=n_rows)
    k = max(int(counts.max()) if len(counts) else 1, 1)
    cols = np.full((n_rows, k), -1, dtype=np.int32)
    vals = np.zeros((n_rows, k), dtype=np.float32)
    ends = np.cumsum(counts)
    starts = ends - counts
    slot = np.arange(len(r)) - starts[r]
    cols[r, slot] = c
    vals[r, slot] = v
    return cols, vals


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def spmv_ell(cols: jax.Array, vals: jax.Array, x: jax.Array,
             block_r: int = 256, block_c: int = 2048,
             interpret: bool = INTERPRET) -> jax.Array:
    """y = A @ x for blocked-ELL A; pad columns are -1."""
    n_r = cols.shape[0]
    cols_p, _ = pad_to(cols.astype(jnp.int32), block_r, 0, -1)
    vals_p, _ = pad_to(vals.astype(jnp.float32), block_r, 0, 0.0)
    x_p, _ = pad_to(x.astype(jnp.float32).reshape(1, -1), block_c, 1, 0.0)
    out = spmv_ell_pallas(cols_p, vals_p, x_p, block_r=block_r,
                          block_c=block_c, interpret=interpret)
    return out[:n_r, 0]
