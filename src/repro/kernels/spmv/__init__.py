from .ops import ell_from_coo, spmv_ell
from .ref import spmv_ell_ref

__all__ = ["ell_from_coo", "spmv_ell", "spmv_ell_ref"]
