"""Pure-jnp oracle for segment_reduce."""
import jax.numpy as jnp


def segment_sum_ref(ids, vals, n_segments: int):
    """Scatter-add; ids < 0 are dropped."""
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    contrib = jnp.where(valid, vals.astype(jnp.float32), 0.0)
    return jnp.zeros((n_segments,), jnp.float32).at[safe].add(contrib)
