"""Segment-sum kernel — the degree-table combiner iterator (paper §III-B).

Accumulo maintains the D4M 2.0 degree table with a server-side *combiner*
iterator (streaming scatter-add). TPUs scatter poorly but matmul superbly,
so the adaptation reduces each block with a one-hot × values matmul on the
MXU:  out[s] += Σ_n 1[ids_n == s] · v_n  =  (vᵀ · onehot)(1, bs).

Grid = (segment_tiles, id_blocks); the id-block axis is innermost so each
output tile accumulates sequentially in VMEM.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(ids_ref, val_ref, o_ref, *, block_s: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    ids = ids_ref[...]                      # (bn, 1) int32, pad = -1
    vals = val_ref[...].astype(jnp.float32)  # (bn, 1)
    local = ids - i * block_s                # segment id within this tile
    lanes = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_s), 1)
    onehot = (local == lanes).astype(jnp.float32)   # (bn, bs); pads match none
    o_ref[...] += jnp.dot(vals.T, onehot,
                          preferred_element_type=jnp.float32)  # (1, bs) MXU


def segment_sum_pallas(ids, vals, *, n_segments: int,
                       block_n: int = 1024, block_s: int = 512,
                       interpret: bool = True):
    """ids: (N, 1) int32 (pad -1); vals: (N, 1); out: (1, S) f32."""
    import functools
    n = ids.shape[0]
    grid = (n_segments // block_s, n // block_n)
    return pl.pallas_call(
        functools.partial(_segsum_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_segments), jnp.float32),
        interpret=interpret,
    )(ids, vals)
