"""jit'd public wrapper for the segment_reduce (combiner) kernel."""
import functools

import jax
import jax.numpy as jnp

from ..common import INTERPRET, pad_to
from .kernel import segment_sum_pallas


@functools.partial(jax.jit, static_argnames=("n_segments", "block_n", "block_s",
                                              "interpret"))
def segment_sum(ids: jax.Array, vals: jax.Array, n_segments: int,
                block_n: int = 1024, block_s: int = 512,
                interpret: bool = INTERPRET) -> jax.Array:
    """Sum ``vals`` into ``n_segments`` buckets by ``ids`` (ids < 0 dropped)."""
    ids_p, _ = pad_to(ids.astype(jnp.int32).reshape(-1, 1), block_n, 0, -1)
    vals_p, _ = pad_to(vals.astype(jnp.float32).reshape(-1, 1), block_n, 0, 0.0)
    s_pad = -(-n_segments // block_s) * block_s
    out = segment_sum_pallas(ids_p, vals_p, n_segments=s_pad,
                             block_n=block_n, block_s=block_s,
                             interpret=interpret)
    return out[0, :n_segments]
