from .ops import segment_sum
from .ref import segment_sum_ref

__all__ = ["segment_sum", "segment_sum_ref"]
