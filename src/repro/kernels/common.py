"""Shared kernel utilities.

Kernels TARGET TPU (pl.pallas_call + BlockSpec VMEM tiling) and VALIDATE on
CPU via interpret mode. ``INTERPRET`` flips automatically.
"""
import jax
import jax.numpy as jnp

INTERPRET = jax.default_backend() != "tpu"

I32_MAX = jnp.iinfo(jnp.int32).max


def pad_to(x, multiple: int, axis: int, value):
    """Pad ``x`` along ``axis`` up to the next multiple; returns (padded, n)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value), n


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
