# Pallas TPU kernels for the paper's compute hot spots (query rank search,
# ingest merge-compaction, degree-combiner, assoc-matvec) plus the serving
# flash-attention kernel. Each subpackage: kernel.py (pl.pallas_call +
# BlockSpec), ops.py (jit'd wrapper), ref.py (pure-jnp oracle). Validated
# with interpret=True on CPU; TPU is the target.
from .flash_attention import flash_attention
from .merge_rank import merge_sorted
from .segment_reduce import segment_sum
from .sorted_search import sorted_search
from .spmv import ell_from_coo, spmv_ell

__all__ = ["flash_attention", "merge_sorted", "segment_sum", "sorted_search",
           "ell_from_coo", "spmv_ell"]
