"""Exporters over the metrics registry: Prometheus text exposition (with
OpenMetrics exemplars), a periodic JSONL emitter, a terminal/markdown
health report, and the debug-bundle writer behind
``DBserver.debug_bundle``.

Everything here is read-only over a Registry/Tracer — exporting never
mutates series, so it is safe to call from a signal handler, a bench
epilogue, or a monitoring thread while the storage path is live.

CLI (reads a registry dump produced by ``Registry.dump`` /
``ingest_bench --metrics-out``):

    python -m repro.obs.export --metrics METRICS_ingest.json            # md
    python -m repro.obs.export --metrics M.json --format term
    python -m repro.obs.export --metrics M.json --prometheus out.prom
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import threading
import time
import zipfile

from .metrics import (_GROWTH, _LO, Histogram, Registry, default_registry)
from .tracing import default_tracer


# ------------------------------------------------------- prometheus text
def _escape_label(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


def _label_str(labels: dict, extra: dict = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape_label(items[k])}"'
                     for k in sorted(items))
    return "{" + inner + "}"


def _fmt(x: float) -> str:
    if x != x:                                   # NaN
        return "NaN"
    if x == math.inf:
        return "+Inf"
    return repr(int(x)) if float(x).is_integer() and abs(x) < 1e15 \
        else repr(float(x))


def prometheus_text(reg: Registry = None) -> str:
    """Render the registry in Prometheus/OpenMetrics text exposition.

    Counters get a ``_total`` suffix; histograms expose cumulative
    ``_bucket{le=...}`` lines over the non-empty log buckets plus
    ``_sum``/``_count``, and buckets that hold an exemplar carry the
    OpenMetrics ``# {trace_id="..."} value`` suffix linking the latency
    band to a span trace id.
    """
    reg = reg if reg is not None else default_registry()
    by_name: dict = {}
    for inst in reg.series():
        by_name.setdefault(inst.name, []).append(inst)
    lines = []
    for name in sorted(by_name):
        insts = sorted(by_name[name],
                       key=lambda i: _label_str(i.labels))
        kind = insts[0].kind
        lines.append(f"# TYPE {name} {kind}")
        for inst in insts:
            if kind == "counter":
                lines.append(f"{name}_total{_label_str(inst.labels)} "
                             f"{_fmt(inst.value)}")
            elif kind == "gauge":
                lines.append(f"{name}{_label_str(inst.labels)} "
                             f"{_fmt(inst.value)}")
            else:
                ex = inst.exemplars()
                cum = 0
                for i, c in enumerate(inst._buckets):
                    if not c:
                        continue
                    cum += c
                    le = _LO * _GROWTH ** i
                    line = (f"{name}_bucket"
                            f"{_label_str(inst.labels, {'le': repr(le)})} "
                            f"{cum}")
                    if i in ex:
                        v, trace = ex[i]
                        line += (f' # {{trace_id="{trace}"}} '
                                 f"{_fmt(v)}")
                    lines.append(line)
                lines.append(f"{name}_bucket"
                             f"{_label_str(inst.labels, {'le': '+Inf'})} "
                             f"{inst.count}")
                lines.append(f"{name}_sum{_label_str(inst.labels)} "
                             f"{_fmt(inst.sum)}")
                lines.append(f"{name}_count{_label_str(inst.labels)} "
                             f"{inst.count}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------- jsonl emitter
class JsonlEmitter:
    """Append one registry snapshot per line to a JSONL file, either on
    demand (`emit_once`) or from a daemon thread every `interval_s`."""

    def __init__(self, path: str, reg: Registry = None,
                 interval_s: float = 15.0):
        self.path = path
        self.reg = reg if reg is not None else default_registry()
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread = None

    def emit_once(self):
        rec = {"ts": time.time(), "metrics": self.reg.snapshot()}
        with open(self.path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        return rec

    def start(self):
        if self._thread is not None:
            return self

        def loop():
            while not self._stop.wait(self.interval_s):
                self.emit_once()

        self._stop.clear()
        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="obs-jsonl-emitter")
        self._thread.start()
        return self

    def stop(self, final_emit: bool = True):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2 * self.interval_s)
            self._thread = None
        if final_emit:
            self.emit_once()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


# ---------------------------------------------------------- health report
def _parse_series_key(key: str):
    """Invert metrics._series_key: 'name{k=v,...}' -> (name, {k: v})."""
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    labels = {}
    for part in rest.rstrip("}").split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


def registry_from_snapshot(snap: dict) -> Registry:
    """Rebuild a Registry from a snapshot() dict (ints -> counters,
    floats -> gauges, dicts -> histograms). Lossy only in that integer
    gauges come back as counters — reads via ``.value`` are unaffected."""
    reg = Registry()
    for key, val in snap.items():
        name, labels = _parse_series_key(key)
        if isinstance(val, dict):
            reg.histogram(name, **labels).load_snapshot(val)
        elif isinstance(val, float):
            reg.gauge(name, **labels).set(val)
        else:
            reg.counter(name, **labels).inc(val)
    return reg


def health_report(snapshot: dict = None, fmt: str = "md") -> str:
    """Render a registry snapshot as a health report.

    Sections: derived health gauges, counters (summed across label sets),
    and latency histograms (count/p50/p99/max, seconds). `fmt` is
    "md" (GitHub-flavored tables) or "term" (aligned plain text).
    """
    snap = snapshot if snapshot is not None else \
        default_registry().snapshot()
    gauges, counters, hists = [], {}, []
    for key, val in sorted(snap.items()):
        name, labels = _parse_series_key(key)
        if isinstance(val, dict):
            hists.append((name, labels, val))
        elif isinstance(val, float) or name.endswith(
                ("_ratio", "_rate", "_occupancy", "_amplification",
                 "_bytes", "_entries", "_runs", "_shapes", "_debt")):
            gauges.append((key, val))
        else:
            agg = counters.setdefault(name, 0)
            counters[name] = agg + val

    def table(header, rows):
        if fmt == "md":
            out = ["| " + " | ".join(header) + " |",
                   "|" + "|".join("---" for _ in header) + "|"]
            out += ["| " + " | ".join(str(c) for c in row) + " |"
                    for row in rows]
            return "\n".join(out)
        widths = [max(len(str(x)) for x in [h] + [r[i] for r in rows])
                  for i, h in enumerate(header)]
        out = ["  ".join(str(h).ljust(w) for h, w in zip(header, widths))]
        out += ["  ".join(str(c).ljust(w) for c, w in zip(row, widths))
                for row in rows]
        return "\n".join(out)

    def head(text):
        return f"### {text}" if fmt == "md" else f"== {text} =="

    parts = [head("Health gauges")]
    if gauges:
        parts.append(table(("gauge", "value"),
                           [(k, f"{v:.6g}") for k, v in gauges]))
    else:
        parts.append("(none)")
    parts.append(head("Counters (summed across labels)"))
    parts.append(table(("counter", "total"), sorted(counters.items())))
    parts.append(head("Latency histograms (s)"))
    rows = []
    for name, labels, h in hists:
        if not h.get("count"):
            continue
        rows.append((_series_label(name, labels), h["count"],
                     f"{h.get('p50', float('nan')):.3e}",
                     f"{h.get('p99', float('nan')):.3e}",
                     f"{h.get('max', float('nan')):.3e}",
                     len(h.get("exemplars", {}))))
    parts.append(table(("series", "count", "p50", "p99", "max",
                        "exemplars"), rows))
    return "\n\n".join(parts) + "\n"


def _series_label(name, labels):
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


# ----------------------------------------------------------- debug bundle
def write_debug_bundle(path: str, reg: Registry = None, tracer=None,
                       extra: dict = None) -> str:
    """One-stop diagnostic archive (zip): registry snapshot + Prometheus
    text + slow traces / flight recordings, plus any `extra` sections
    (JSON-serializable, one member per key). This is the engine under
    ``DBserver.debug_bundle`` and the bench debug-bundle artifact."""
    reg = reg if reg is not None else default_registry()
    tracer = tracer if tracer is not None else default_tracer()
    with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as zf:
        zf.writestr("metrics.json",
                    json.dumps(reg.snapshot(), indent=1, sort_keys=True))
        zf.writestr("prometheus.txt", prometheus_text(reg))
        zf.writestr("slow_traces.json", json.dumps(
            {"slow_threshold_s": tracer.slow_threshold_s,
             "slow_ops": tracer.slow_ops(),
             "flight_recordings": tracer.flight_recordings()}, indent=1))
        for name, payload in (extra or {}).items():
            zf.writestr(f"{name}.json",
                        json.dumps(payload, indent=1, sort_keys=True))
    return path


# -------------------------------------------------------------------- cli
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a metrics registry dump as a health report "
                    "or Prometheus exposition.")
    ap.add_argument("--metrics", required=True,
                    help="registry snapshot JSON (Registry.dump output)")
    ap.add_argument("--format", choices=("md", "term"), default="md")
    ap.add_argument("--prometheus", metavar="PATH",
                    help="also write Prometheus text exposition here")
    ap.add_argument("--out", metavar="PATH",
                    help="write the report here instead of stdout")
    args = ap.parse_args(argv)
    with open(args.metrics) as f:
        snap = json.load(f)
    if "tables" in snap and "aggregate" in snap:
        ap.error(f"{args.metrics} is a DBserver.dump_metrics() view, not a "
                 "raw registry snapshot — feed it Registry.dump() output "
                 "(e.g. metrics.json from DBserver.debug_bundle)")
    if args.prometheus:
        with open(args.prometheus, "w") as f:
            f.write(prometheus_text(registry_from_snapshot(snap)))
    report = health_report(snap, fmt=args.format)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report)
    else:
        sys.stdout.write(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary and args.format == "md":
        with open(summary, "a") as f:
            f.write("\n## Health report\n\n" + report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
