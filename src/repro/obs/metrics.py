"""Dependency-free metrics registry: counters, gauges, and log-bucketed
HDR-style histograms with labeled series.

Design constraints (ISSUE 6):
  * p50/p90/p99/p999 without storing samples -> fixed log-spaced buckets.
  * labeled series (table=..., shard=..., op=...) under one metric name.
  * near-zero overhead when disabled: every mutator checks a single
    registry-level flag and returns immediately.
  * process-global default registry so instrumentation sites never need
    plumbing; tests and benchmarks may build private registries.

Histogram math: bucket edges grow by 2**(1/SUBBUCKETS) per bin (8
sub-buckets per octave), so any sample's bucket representative (the
geometric midpoint) is within ~4.4% relative error of the true value.
count/sum/min/max are tracked exactly, and quantile() clamps to
[min, max] so constant distributions report exact quantiles.
"""
from __future__ import annotations

import json
import math
import threading

from .tracing import current_trace as _current_trace

# ---------------------------------------------------------------- histogram
_SUBBUCKETS = 8                      # bins per octave (factor 2**(1/8))
_GROWTH = 2.0 ** (1.0 / _SUBBUCKETS)
_LOG_GROWTH = math.log(_GROWTH)
_LO = 1e-9                           # smallest resolvable sample (1 ns)
_NBINS = 512                         # covers _LO .. _LO*_GROWTH**512 ~ 2e10


def _bucket_index(x: float) -> int:
    if x <= _LO:
        return 0
    i = int(math.log(x / _LO) / _LOG_GROWTH) + 1
    return i if i < _NBINS else _NBINS - 1


def _bucket_rep(i: int) -> float:
    """Geometric midpoint of bucket i (representative value)."""
    if i <= 0:
        return _LO
    return _LO * _GROWTH ** (i - 0.5)


class Histogram:
    """Log-bucketed latency histogram. Units are the caller's (we use
    seconds everywhere in repro.db)."""

    kind = "histogram"

    def __init__(self, registry: "Registry", name: str, labels: dict):
        self._reg = registry
        self.name = name
        self.labels = labels
        self.reset()

    def reset(self):
        self._buckets = [0] * _NBINS
        self._exemplars = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, x: float):
        if not self._reg.enabled:
            return
        x = float(x)
        i = _bucket_index(x)
        self._buckets[i] += 1
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        trace = _current_trace()
        if trace is not None:
            # latest exemplar per bucket: which op landed in this latency
            # band last -> join against the tracer's flight recordings
            self._exemplars[i] = (x, trace)

    def exemplars(self) -> dict:
        """{bucket_index: (value, trace_id)} — latest sample per bucket
        that was observed while a span was open."""
        return dict(self._exemplars)

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile from bucket counts, clamped to the exact
        [min, max] envelope. Returns nan when empty."""
        if self.count == 0:
            return math.nan
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i, c in enumerate(self._buckets):
            seen += c
            if seen >= rank:
                return min(max(_bucket_rep(i), self.min), self.max)
        return self.max

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99), "p999": self.quantile(0.999)}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def merge(self, other: "Histogram"):
        """Fold another histogram's state into this one (exact: same fixed
        bucket layout)."""
        for i, c in enumerate(other._buckets):
            if c:
                self._buckets[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        self._exemplars.update(other._exemplars)

    def snapshot(self) -> dict:
        s = {"count": int(self.count), "sum": float(self.sum)}
        if self.count:
            s["min"] = float(self.min)
            s["max"] = float(self.max)
            s["mean"] = float(self.mean)
            s.update({k: float(v) for k, v in self.percentiles().items()})
            s["buckets"] = {str(i): int(c)
                            for i, c in enumerate(self._buckets) if c}
            if self._exemplars:
                s["exemplars"] = {str(i): {"value": float(v), "trace": t}
                                  for i, (v, t)
                                  in sorted(self._exemplars.items())}
        return s

    def load_snapshot(self, snap: dict):
        """Merge a snapshot() dict (e.g. from another process) into self."""
        self.count += int(snap.get("count", 0))
        self.sum += float(snap.get("sum", 0.0))
        if "min" in snap:
            self.min = min(self.min, float(snap["min"]))
        if "max" in snap:
            self.max = max(self.max, float(snap["max"]))
        for i, c in snap.get("buckets", {}).items():
            self._buckets[int(i)] += int(c)
        for i, ex in snap.get("exemplars", {}).items():
            self._exemplars[int(i)] = (float(ex["value"]), ex["trace"])


class Counter:
    kind = "counter"

    def __init__(self, registry: "Registry", name: str, labels: dict):
        self._reg = registry
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n=1):
        if self._reg.enabled:
            self.value += n

    def reset(self):
        self.value = 0

    def snapshot(self):
        v = self.value
        return int(v) if isinstance(v, (bool, int)) else float(v)


class Gauge:
    kind = "gauge"

    def __init__(self, registry: "Registry", name: str, labels: dict):
        self._reg = registry
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v):
        if self._reg.enabled:
            self.value = v

    def reset(self):
        self.value = 0.0

    def snapshot(self):
        v = self.value
        return int(v) if isinstance(v, (bool, int)) else float(v)


# ----------------------------------------------------------------- registry
def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Get-or-create store of labeled metric series.

    A series is (name, labels) -> instrument; calling counter()/gauge()/
    histogram() twice with the same identity returns the same object, so
    instrumentation sites can cache or re-request freely. `enabled` is the
    single kill switch every mutator checks.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._series: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict):
        key = _series_key(name, labels)
        inst = self._series.get(key)
        if inst is None:
            with self._lock:
                inst = self._series.get(key)
                if inst is None:
                    inst = cls(self, name, dict(labels))
                    self._series[key] = inst
        if not isinstance(inst, cls):
            raise TypeError(f"series {key!r} already registered as "
                            f"{inst.kind}, not {cls.kind.lower()}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    # -- bulk ops ----------------------------------------------------------
    def series(self, name: str = None, **label_filter):
        """All instruments matching name (prefix ignored if None) and the
        given label values."""
        out = []
        for inst in self._series.values():
            if name is not None and inst.name != name:
                continue
            if any(str(inst.labels.get(k)) != str(v)
                   for k, v in label_filter.items()):
                continue
            out.append(inst)
        return out

    def reset(self, name: str = None, **label_filter):
        for inst in self.series(name, **label_filter):
            inst.reset()

    def snapshot(self, name: str = None, **label_filter) -> dict:
        """JSON-ready {series_key: value-or-histogram-dict}, sorted."""
        out = {}
        for inst in self.series(name, **label_filter):
            out[_series_key(inst.name, inst.labels)] = inst.snapshot()
        return dict(sorted(out.items()))

    def aggregate(self, name: str, **label_filter):
        """Sum counters / merge histograms across all series of `name`
        matching the filter. Returns an int/float for counters, a merged
        snapshot dict for histograms, None if no series exist."""
        insts = self.series(name, **label_filter)
        if not insts:
            return None
        if insts[0].kind == "histogram":
            pooled = Histogram(self, name, {})
            for h in insts:
                pooled.merge(h)
            return pooled.snapshot()
        total = 0
        for c in insts:
            total += c.value
        return int(total) if isinstance(total, (bool, int)) else float(total)

    def dump(self, path: str, **label_filter):
        with open(path, "w") as f:
            json.dump(self.snapshot(**label_filter), f, indent=1,
                      sort_keys=True)


def merge_snapshots(snapshots) -> dict:
    """Merge per-process registry snapshot() dicts at the host: counters
    and gauges sum; histograms bucket-merge with recomputed percentiles."""
    reg = Registry()
    merged = {}
    for snap in snapshots:
        for key, val in snap.items():
            if isinstance(val, dict):        # histogram snapshot
                h = merged.get(key)
                if h is None:
                    h = merged[key] = Histogram(reg, key, {})
                h.load_snapshot(val)
            else:
                merged[key] = merged.get(key, 0) + val
    return {k: (v.snapshot() if isinstance(v, Histogram) else v)
            for k, v in sorted(merged.items())}


# ------------------------------------------------------------------ globals
_DEFAULT = Registry(enabled=True)


def default_registry() -> Registry:
    return _DEFAULT


def set_enabled(on: bool):
    """Toggle the process-global registry (and nothing else; the tracer has
    its own switch in repro.obs.tracing)."""
    _DEFAULT.enabled = bool(on)
