"""repro.obs — lightweight, dependency-free observability.

Metrics (counters / gauges / log-bucketed histograms with labeled series)
plus nested wall-time span tracing with ring-buffer retention and
Chrome-trace export. See src/repro/db/README.md "Observability" for the
metric catalog and span taxonomy used by the database stack.
"""
from .metrics import (Counter, Gauge, Histogram, Registry, default_registry,
                      merge_snapshots, set_enabled)
from .tracing import (Tracer, current_trace, default_tracer, set_tracing,
                      span)
from .export import (JsonlEmitter, health_report, prometheus_text,
                     write_debug_bundle)

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "default_registry",
    "merge_snapshots", "set_enabled",
    "Tracer", "current_trace", "default_tracer", "set_tracing", "span",
    "JsonlEmitter", "health_report", "prometheus_text",
    "write_debug_bundle",
]
