"""Per-op span tracing: nested wall-time spans in a bounded ring buffer,
a slow-op log, and Chrome-trace / plain-JSON export.

    with span("flush", table="t", shard=3):
        ...
        with span("host_sync", table="t"):
            ...

Spans record host wall time. Under JAX async dispatch that means a
"dispatch" span measures enqueue cost and a "host_sync" span measures the
device round-trip — which is exactly the split the fused read path is
designed around (one dispatch + one sync per query batch).

Disabled mode hands back a shared no-op context manager: the only cost at
a call site is one attribute check and one function call.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "labels", "t0", "ts", "depth", "parent")

    def __init__(self, tracer, name, labels):
        self.tracer = tracer
        self.name = name
        self.labels = labels

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else None
        stack.append(self)
        self.ts = time.time()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        tr = self.tracer
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        rec = {"name": self.name, "ts": self.ts, "dur": dur,
               "depth": self.depth, "parent": self.parent,
               "tid": threading.get_ident()}
        if self.labels:
            rec["labels"] = self.labels
        tr._ring.append(rec)
        if dur >= tr.slow_threshold_s:
            tr._slow.append(rec)
        return False


class Tracer:
    def __init__(self, capacity: int = 8192, slow_threshold_s: float = 0.050,
                 slow_capacity: int = 256, enabled: bool = True):
        self.enabled = enabled
        self.slow_threshold_s = slow_threshold_s
        self._ring = deque(maxlen=capacity)
        self._slow = deque(maxlen=slow_capacity)
        self._local = threading.local()

    def _stack(self):
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **labels):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    # -- inspection / export ----------------------------------------------
    def spans(self):
        """Ring-buffer contents, oldest first."""
        return list(self._ring)

    def slow_ops(self):
        """Spans that exceeded slow_threshold_s, oldest first."""
        return list(self._slow)

    def clear(self):
        self._ring.clear()
        self._slow.clear()

    def export_json(self, path: str):
        with open(path, "w") as f:
            json.dump({"slow_threshold_s": self.slow_threshold_s,
                       "spans": self.spans(),
                       "slow_ops": self.slow_ops()}, f, indent=1)

    def export_chrome(self, path: str):
        """chrome://tracing / Perfetto 'complete' (ph=X) events, one per
        span, ts/dur in microseconds."""
        events = []
        for rec in self._ring:
            events.append({
                "name": rec["name"], "cat": "repro.db", "ph": "X",
                "ts": rec["ts"] * 1e6, "dur": rec["dur"] * 1e6,
                "pid": 0, "tid": rec["tid"],
                "args": dict(rec.get("labels", {}),
                             depth=rec["depth"], parent=rec["parent"]),
            })
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f, indent=1)


# ------------------------------------------------------------------ globals
_DEFAULT = Tracer()


def default_tracer() -> Tracer:
    return _DEFAULT


def span(name: str, **labels):
    """Span on the process-global default tracer."""
    return _DEFAULT.span(name, **labels)


def set_tracing(on: bool):
    _DEFAULT.enabled = bool(on)
