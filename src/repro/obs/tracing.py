"""Per-op span tracing: nested wall-time spans in a bounded ring buffer,
a slow-op log, trace-context propagation, a flight recorder for slow
ops, and Chrome-trace / plain-JSON export.

    with span("flush", table="t", shard=3):
        ...
        with span("host_sync", table="t"):
            ...

Spans record host wall time. Under JAX async dispatch that means a
"dispatch" span measures enqueue cost and a "host_sync" span measures the
device round-trip — which is exactly the split the fused read path is
designed around (one dispatch + one sync per query batch).

Trace context: the root span of each nesting (depth 0) allocates a trace
id (``t<hex>``); every child span inherits it, so one connector-level op
(insert/query/scan/compaction) shares a single id from connector through
kvstore, engine, and WAL. `current_trace()` exposes the active id so
histograms can attach exemplars linking latency buckets back to traces.

Flight recorder: when a ROOT span exceeds `slow_threshold_s`, its full
span tree (root + all descendants, in completion order) is captured into
a bounded ring — `flight_recordings()` — so a slow query can be explained
after the fact without re-running under a profiler.

Disabled mode hands back a shared no-op context manager: the only cost at
a call site is one attribute check and one function call.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque


class _NullSpan:
    """Shared do-nothing context manager for the disabled path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "labels", "t0", "ts", "depth", "parent",
                 "trace")

    def __init__(self, tracer, name, labels):
        self.tracer = tracer
        self.name = name
        self.labels = labels

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        self.depth = len(stack)
        if stack:
            self.parent = stack[-1].name
            self.trace = stack[-1].trace
        else:
            self.parent = None
            self.trace = "t%06x" % next(tr._trace_seq)
            tr._local.tree = []
        stack.append(self)
        self.ts = time.time()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        tr = self.tracer
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        rec = {"name": self.name, "ts": self.ts, "dur": dur,
               "depth": self.depth, "parent": self.parent,
               "trace": self.trace, "tid": threading.get_ident()}
        if self.labels:
            rec["labels"] = self.labels
        tr._ring.append(rec)
        if dur >= tr.slow_threshold_s:
            tr._slow.append(rec)
        tree = getattr(tr._local, "tree", None)
        if tree is not None:
            tree.append(rec)
            if self.depth == 0:
                if dur >= tr.slow_threshold_s:
                    tr._flight.append({"trace": self.trace, "root": rec,
                                       "spans": tree})
                tr._local.tree = None
        return False


class Tracer:
    def __init__(self, capacity: int = 8192, slow_threshold_s: float = 0.050,
                 slow_capacity: int = 256, flight_capacity: int = 64,
                 enabled: bool = True):
        self.enabled = enabled
        self.slow_threshold_s = slow_threshold_s
        self._ring = deque(maxlen=capacity)
        self._slow = deque(maxlen=slow_capacity)
        self._flight = deque(maxlen=flight_capacity)
        self._trace_seq = itertools.count(1)
        self._local = threading.local()

    def _stack(self):
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **labels):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    def current_trace_id(self):
        """Trace id of the innermost open span on this thread, or None."""
        st = getattr(self._local, "stack", None)
        return st[-1].trace if st else None

    # -- inspection / export ----------------------------------------------
    def spans(self):
        """Ring-buffer contents, oldest first."""
        return list(self._ring)

    def slow_ops(self):
        """Spans that exceeded slow_threshold_s, oldest first."""
        return list(self._slow)

    def flight_recordings(self):
        """Full span trees of root ops that exceeded slow_threshold_s,
        oldest first: {trace, root, spans} with spans in completion
        order (children before their parent)."""
        return list(self._flight)

    def clear(self):
        self._ring.clear()
        self._slow.clear()
        self._flight.clear()

    def export_json(self, path: str):
        with open(path, "w") as f:
            json.dump({"slow_threshold_s": self.slow_threshold_s,
                       "spans": self.spans(),
                       "slow_ops": self.slow_ops(),
                       "flight_recordings": self.flight_recordings()},
                      f, indent=1)

    def export_chrome(self, path: str):
        """chrome://tracing / Perfetto 'complete' (ph=X) events, one per
        span, ts/dur in microseconds."""
        events = []
        for rec in self._ring:
            events.append({
                "name": rec["name"], "cat": "repro.db", "ph": "X",
                "ts": rec["ts"] * 1e6, "dur": rec["dur"] * 1e6,
                "pid": 0, "tid": rec["tid"],
                "args": dict(rec.get("labels", {}),
                             depth=rec["depth"], parent=rec["parent"],
                             trace=rec.get("trace")),
            })
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f, indent=1)


# ------------------------------------------------------------------ globals
_DEFAULT = Tracer()


def default_tracer() -> Tracer:
    return _DEFAULT


def span(name: str, **labels):
    """Span on the process-global default tracer."""
    return _DEFAULT.span(name, **labels)


def current_trace():
    """Trace id of the innermost open span on the default tracer (this
    thread), or None when no span is open / tracing is disabled."""
    st = getattr(_DEFAULT._local, "stack", None)
    return st[-1].trace if st else None


def set_tracing(on: bool):
    _DEFAULT.enabled = bool(on)
